//! Per-PE in-memory replica storage.
//!
//! Each PE stores `r` permuted *slices* (one per copy level, see
//! [`Distribution::stored_slice`]) plus any replicas re-created by §IV-E
//! repair. A slice is a contiguous interval of the permuted block ID space,
//! so the store is just flat buffers plus interval arithmetic; the slice
//! list is kept **sorted by start** so `read`/`write`/`holds` are a single
//! binary search — O(log(r + f)) with `f` repair-added slices — instead of
//! the former linear scan. The per-PE memory is exactly the `r·n/p` blocks
//! of the paper's §IV-C analysis (asserted in tests and the
//! `ablation_memory` bench).

use crate::restore::block::BlockRange;
use crate::restore::distribution::Distribution;
use crate::restore::hashing::block_checksum;

/// Seed of the per-block checksum family. The permuted block id is mixed
/// in (`CHECKSUM_SEED ^ y`), so a checksum binds both the content *and*
/// the position of a block — an intact block served from the wrong offset
/// fails verification just like a bit flip.
pub const CHECKSUM_SEED: u64 = 0x1DE7_EC7A_B10C_4B5F;

/// Checksum of permuted block `y` with content `bytes`.
#[inline]
pub fn checksum_of(y: u64, bytes: &[u8]) -> u64 {
    block_checksum(CHECKSUM_SEED ^ y, bytes)
}

/// Storage payload of one slice.
#[derive(Debug, Clone)]
pub enum SliceBuf {
    /// Execution mode: the actual serialized blocks.
    Real(Vec<u8>),
    /// Cost-model mode: byte length only.
    Virtual(u64),
}

impl SliceBuf {
    pub fn len(&self) -> u64 {
        match self {
            SliceBuf::Real(v) => v.len() as u64,
            SliceBuf::Virtual(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One stored slice: its permuted interval, the bytes, and the per-block
/// integrity checksums.
#[derive(Debug, Clone)]
pub struct StoredSlice {
    pub range: BlockRange,
    pub buf: SliceBuf,
    /// One checksum per block ([`checksum_of`]), maintained by every write
    /// path (`insert`/`write`/`write_from`) so it always reflects the
    /// legitimately-written content — a divergence IS the definition of
    /// silent corruption. Empty in cost-model mode (a `Virtual` buf has no
    /// bytes to sum; verification is a no-op there).
    pub sums: Vec<u64>,
}

/// The replica store of a single PE.
#[derive(Debug, Clone, Default)]
pub struct PeStore {
    slices: Vec<StoredSlice>,
    block_size: usize,
}

impl PeStore {
    pub fn new(block_size: usize) -> Self {
        PeStore { slices: Vec::new(), block_size }
    }

    /// Insert a slice, keeping the list sorted by `range.start` (callers
    /// never insert overlapping slices — submit places disjoint stored
    /// slices, repair checks `holds` first). Real payloads get their
    /// per-block checksums latched from the inserted content.
    pub fn insert(&mut self, range: BlockRange, buf: SliceBuf) {
        debug_assert_eq!(buf.len(), range.len() * self.block_size as u64);
        let sums = match &buf {
            SliceBuf::Real(v) => {
                let bs = self.block_size;
                (0..range.len())
                    .map(|b| checksum_of(range.start + b, &v[(b as usize * bs)..][..bs]))
                    .collect()
            }
            SliceBuf::Virtual(_) => Vec::new(),
        };
        let at = self.slices.partition_point(|s| s.range.start < range.start);
        self.slices.insert(at, StoredSlice { range, buf, sums });
    }

    /// Remove the stored slice exactly covering `[start, start + len)` —
    /// the scrub quarantine primitive: a corrupt copy is dropped so §IV-E
    /// repair can re-create it from a verified survivor. Returns whether a
    /// slice was removed (false when nothing stored or the stored slice is
    /// wider than the asked range — quarantine is slot-granular, matching
    /// how submit/repair place whole slices).
    pub fn remove(&mut self, start: u64, len: u64) -> bool {
        match self.find_idx(start, len) {
            Some(i) if self.slices[i].range == BlockRange::new(start, start + len) => {
                self.slices.remove(i);
                true
            }
            _ => false,
        }
    }

    /// Stored slices, sorted by permuted start.
    pub fn slices(&self) -> &[StoredSlice] {
        &self.slices
    }

    /// Index of the stored slice fully containing `[start, start + len)`,
    /// found by binary search over the sorted slice list.
    #[inline]
    fn find_idx(&self, start: u64, len: u64) -> Option<usize> {
        if len == 0 {
            return None;
        }
        // Last slice starting at or before `start` is the only candidate:
        // slices are disjoint, so any container must start there.
        let i = self.slices.partition_point(|s| s.range.start <= start);
        let s = &self.slices[i.checked_sub(1)?];
        (start + len <= s.range.end).then_some(i - 1)
    }

    /// The stored slice fully containing `[start, start + len)`, if any —
    /// the slice-cursor API: the load path resolves each coalesced run's
    /// source slice once instead of scanning per piece.
    pub fn find_slice(&self, start: u64, len: u64) -> Option<&StoredSlice> {
        self.find_idx(start, len).map(|i| &self.slices[i])
    }

    /// Total bytes resident in this PE's replica store (§IV-C accounting).
    pub fn resident_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.buf.len()).sum()
    }

    /// Read `len` blocks starting at permuted block `start`; returns the
    /// bytes (execution mode) or None (cost-model mode). Panics if the
    /// range is not stored — callers must route via the distribution.
    pub fn read(&self, start: u64, len: u64) -> Option<&[u8]> {
        let Some(s) = self.find_slice(start, len) else {
            panic!("PeStore::read: permuted range [{start}, {}) not stored", start + len);
        };
        match &s.buf {
            SliceBuf::Real(v) => {
                let off = ((start - s.range.start) * self.block_size as u64) as usize;
                let n = (len * self.block_size as u64) as usize;
                Some(&v[off..off + n])
            }
            SliceBuf::Virtual(_) => None,
        }
    }

    /// Does this PE hold the given permuted range?
    pub fn holds(&self, start: u64, len: u64) -> bool {
        self.find_idx(start, len).is_some()
    }

    /// The latched checksum of permuted block `y`, if this PE stores it in
    /// a `Real` slice — the delta-resubmit comparator: a new version's
    /// block is unchanged exactly when `checksum_of(y, new_bytes)` equals
    /// this stored sum. Returns `None` for unstored ranges and `Virtual`
    /// slices (cost-model datasets carry no sums; their callers must pass
    /// an explicit dirty set).
    pub fn block_sum(&self, y: u64) -> Option<u64> {
        let i = self.find_idx(y, 1)?;
        let s = &self.slices[i];
        match &s.buf {
            SliceBuf::Real(_) => Some(s.sums[(y - s.range.start) as usize]),
            SliceBuf::Virtual(_) => None,
        }
    }

    /// Write `bytes` into an already-inserted `Real` slice straight from a
    /// borrowed source slice — the zero-copy submit path: no intermediate
    /// `Vec` per written unit. `bytes.len()` must be a whole number of
    /// blocks; writing into a `Virtual` slice only validates the range.
    pub fn write_from(&mut self, start: u64, bytes: &[u8]) {
        debug_assert_eq!(bytes.len() % self.block_size, 0);
        let len = (bytes.len() / self.block_size) as u64;
        let Some(i) = self.find_idx(start, len) else {
            panic!("PeStore::write_from: permuted range [{start}, {}) not stored", start + len);
        };
        let s = &mut self.slices[i];
        if let SliceBuf::Real(dst) = &mut s.buf {
            let off = ((start - s.range.start) * self.block_size as u64) as usize;
            dst[off..off + bytes.len()].copy_from_slice(bytes);
            resum(self.block_size, s.range.start, dst, &mut s.sums, start, len);
        }
    }

    /// Drop every stored slice (shrink-mode memory reclaim for a dead PE).
    pub fn clear(&mut self) {
        self.slices.clear();
    }

    /// Write bytes into an already-inserted slice (repair path).
    pub fn write(&mut self, start: u64, bytes_or_len: &SliceBuf) {
        let len = match bytes_or_len {
            SliceBuf::Real(v) => v.len() as u64 / self.block_size as u64,
            SliceBuf::Virtual(n) => n / self.block_size as u64,
        };
        let Some(i) = self.find_idx(start, len) else {
            panic!("PeStore::write: permuted range [{start}, {}) not stored", start + len);
        };
        let s = &mut self.slices[i];
        if let (SliceBuf::Real(dst), SliceBuf::Real(src)) = (&mut s.buf, bytes_or_len) {
            let off = ((start - s.range.start) * self.block_size as u64) as usize;
            dst[off..off + src.len()].copy_from_slice(src);
            resum(self.block_size, s.range.start, dst, &mut s.sums, start, len);
        }
    }

    /// Verify the stored checksums of `[start, start + len)` against the
    /// current buffer content; returns the first mismatching permuted
    /// block id, or None when everything checks out. Allocation-free —
    /// this runs on the steady-state load path for every assembled run.
    /// A `Virtual` slice has no bytes and verifies trivially. Panics if
    /// the range is not stored (callers route via the distribution, like
    /// [`PeStore::read`]).
    pub fn verify(&self, start: u64, len: u64) -> Option<u64> {
        let Some(s) = self.find_slice(start, len) else {
            panic!("PeStore::verify: permuted range [{start}, {}) not stored", start + len);
        };
        let SliceBuf::Real(v) = &s.buf else { return None };
        let bs = self.block_size;
        for b in 0..len {
            let y = start + b;
            let at = (y - s.range.start) as usize;
            if checksum_of(y, &v[at * bs..][..bs]) != s.sums[at] {
                return Some(y);
            }
        }
        None
    }

    /// Count the corrupt blocks in `[start, start + len)` (0 = clean) —
    /// the scrub scanner's bulk form of [`PeStore::verify`]. Same
    /// allocation-free walk, same panics-if-unstored contract.
    pub fn corrupt_blocks(&self, start: u64, len: u64) -> u64 {
        let Some(s) = self.find_slice(start, len) else {
            panic!("PeStore::corrupt_blocks: permuted range [{start}, {}) not stored", start + len);
        };
        let SliceBuf::Real(v) = &s.buf else { return 0 };
        let bs = self.block_size;
        (0..len)
            .filter(|&b| {
                let y = start + b;
                let at = (y - s.range.start) as usize;
                checksum_of(y, &v[at * bs..][..bs]) != s.sums[at]
            })
            .count() as u64
    }

    /// Bytes resident in `Real` payloads only — the corruptible surface
    /// the fault injector samples over (`Virtual` slices have no bytes a
    /// bit flip could land on).
    pub fn real_bytes(&self) -> u64 {
        self.slices
            .iter()
            .map(|s| match &s.buf {
                SliceBuf::Real(v) => v.len() as u64,
                SliceBuf::Virtual(_) => 0,
            })
            .sum()
    }

    /// Flip one stored bit — the silent-corruption injection primitive.
    /// `off` indexes the concatenation of this PE's `Real` payloads in
    /// slice order (`[0, real_bytes())`); the checksums are deliberately
    /// NOT updated (that divergence is what detection looks for). Returns
    /// the permuted block id whose content changed, or None when `off` is
    /// past the resident real bytes.
    pub fn corrupt_bit_at(&mut self, off: u64, bit: u8) -> Option<u64> {
        let mut skip = off;
        for s in &mut self.slices {
            if let SliceBuf::Real(v) = &mut s.buf {
                if skip < v.len() as u64 {
                    v[skip as usize] ^= 1 << (bit & 7);
                    return Some(s.range.start + skip / self.block_size as u64);
                }
                skip -= v.len() as u64;
            }
        }
        None
    }

    /// Flip `bit` of the first byte of permuted block `y`, if this PE
    /// stores it in a `Real` slice — the block-addressed form of
    /// [`PeStore::corrupt_bit_at`], used by tests that must corrupt a
    /// *specific* block on a *specific* holder (e.g. all `r` copies at
    /// once to prove the all-replicas-corrupt path). Checksums are
    /// deliberately NOT updated. Returns whether a stored byte changed.
    pub fn corrupt_block_bit(&mut self, y: u64, bit: u8) -> bool {
        let Some(i) = self.find_idx(y, 1) else { return false };
        let bs = self.block_size;
        let s = &mut self.slices[i];
        if let SliceBuf::Real(v) = &mut s.buf {
            v[(y - s.range.start) as usize * bs] ^= 1 << (bit & 7);
            true
        } else {
            false
        }
    }
}

/// Recompute the checksums of blocks `[start, start + len)` of a slice
/// starting at `slice_start` whose full payload is `buf` — shared by the
/// write paths, allocation-free.
#[inline]
fn resum(block_size: usize, slice_start: u64, buf: &[u8], sums: &mut [u64], start: u64, len: u64) {
    for b in 0..len {
        let y = start + b;
        let at = (y - slice_start) as usize;
        sums[at] = checksum_of(y, &buf[at * block_size..][..block_size]);
    }
}

/// Inline holder capacity per slot of the flattened [`HolderIndex`]: the
/// common replication levels (the paper benchmarks r = 2..4) fit entirely
/// in the flat inline table; slots that accumulate more holders (repair
/// re-replication, high-`r` configs) spill to a per-slot overflow list.
const SLOT_INLINE: usize = 4;

/// Reverse holder index: permuted *slot* (slice number,
/// [`Distribution::slice_of`] of the slice start) → sorted list of PEs
/// currently storing that slot's slice.
///
/// Both submit and §IV-E repair place whole slices, so slot granularity is
/// exact. The index is maintained incrementally ([`HolderIndex::insert`] on
/// every slice placement, [`HolderIndex::drop_pe`] when a PE's store is
/// reclaimed) and replaces the O(p)-per-unit store sweep that repair
/// planning and the load path's post-repair fallback used to perform —
/// O(p²) per repair at the paper's p = 24 576, now O(r + f) per unit.
/// Consistency with a from-scratch store scan is enforced by
/// [`HolderIndex::rebuild`]-based property tests.
///
/// ## Layout (million-rank scale)
///
/// The former representation — one `Vec<u32>` per slot — allocated a heap
/// buffer for every non-empty slot and made `drop_pe` an O(all slots)
/// sweep. Holders now live in a **flat inline table** (`SLOT_INLINE`
/// entries per slot, one allocation for the whole index) with a sparse
/// per-slot overflow map for the rare slots exceeding the inline capacity,
/// and a **pe → slots reverse map** makes `drop_pe` (dead-PE reclaim) and
/// scrub quarantine cost O(slots actually held by that PE). Equality
/// compares per-slot holder *content* — a slot that spilled and shrank
/// back compares equal to one that never spilled.
#[derive(Debug, Clone, Default)]
pub struct HolderIndex {
    /// `slots() * SLOT_INLINE` flat inline holder storage; entry `i` of
    /// slot `s` is `inline[s * SLOT_INLINE + i]`, sorted, the first
    /// `counts[s]` valid (unless spilled to `overflow`).
    inline: Vec<u32>,
    /// Holder count per slot (including spilled slots).
    counts: Vec<u32>,
    /// Full sorted holder list of slots whose count exceeds
    /// `SLOT_INLINE`; entries migrate back inline when they shrink.
    overflow: std::collections::HashMap<u32, Vec<u32>>,
    /// pe → sorted slots held, grown on demand (cluster ranks can exceed
    /// the slot count when spare PEs adopt replicas).
    rev: Vec<Vec<u32>>,
}

impl HolderIndex {
    pub fn new(slots: usize) -> Self {
        HolderIndex {
            inline: vec![0; slots * SLOT_INLINE],
            counts: vec![0; slots],
            overflow: std::collections::HashMap::new(),
            rev: Vec::new(),
        }
    }

    /// Number of tracked slots (0 before submit).
    pub fn slots(&self) -> usize {
        self.counts.len()
    }

    /// Record that `pe` now stores slot `slot` (idempotent, keeps the
    /// holder list sorted for deterministic iteration order).
    pub fn insert(&mut self, slot: usize, pe: usize) {
        let pe32 = pe as u32;
        let n = self.counts[slot] as usize;
        if let Some(ov) = self.overflow.get_mut(&(slot as u32)) {
            match ov.binary_search(&pe32) {
                Ok(_) => return,
                Err(at) => ov.insert(at, pe32),
            }
        } else if n < SLOT_INLINE {
            let base = slot * SLOT_INLINE;
            match self.inline[base..base + n].binary_search(&pe32) {
                Ok(_) => return,
                Err(at) => {
                    self.inline.copy_within(base + at..base + n, base + at + 1);
                    self.inline[base + at] = pe32;
                }
            }
        } else {
            // Spill: the slot outgrew its inline entries — move them to
            // an overflow list holding the slot's FULL sorted holder set.
            let base = slot * SLOT_INLINE;
            let mut v = self.inline[base..base + SLOT_INLINE].to_vec();
            match v.binary_search(&pe32) {
                Ok(_) => return,
                Err(at) => v.insert(at, pe32),
            }
            self.overflow.insert(slot as u32, v);
        }
        self.counts[slot] += 1;
        self.rev_insert(pe, slot);
    }

    /// Remove `pe` from every slot's holder list (store reclaimed) — via
    /// the reverse map, O(slots held by `pe`), not O(all slots).
    pub fn drop_pe(&mut self, pe: usize) {
        if pe >= self.rev.len() {
            return;
        }
        let held = std::mem::take(&mut self.rev[pe]);
        for &slot in &held {
            let existed = self.forward_remove(slot as usize, pe);
            debug_assert!(existed, "reverse map out of sync with forward index");
        }
    }

    /// Remove `pe` from a single slot's holder list — the quarantine
    /// primitive: scrub drops only the corrupt copy's membership, leaving
    /// the holder's other (clean) slices routable. Returns whether the
    /// entry existed.
    pub fn remove(&mut self, slot: usize, pe: usize) -> bool {
        let existed = self.forward_remove(slot, pe);
        if existed {
            self.rev_remove(pe, slot);
        }
        existed
    }

    /// Remove `pe` from slot `slot`'s forward holder list only (the
    /// reverse-map side is the caller's responsibility).
    fn forward_remove(&mut self, slot: usize, pe: usize) -> bool {
        let pe32 = pe as u32;
        if let Some(ov) = self.overflow.get_mut(&(slot as u32)) {
            let Ok(at) = ov.binary_search(&pe32) else { return false };
            ov.remove(at);
            self.counts[slot] -= 1;
            if self.counts[slot] as usize <= SLOT_INLINE {
                // Un-spill eagerly so the representation (and memory)
                // tracks the content.
                let v = self.overflow.remove(&(slot as u32)).unwrap();
                let base = slot * SLOT_INLINE;
                self.inline[base..base + v.len()].copy_from_slice(&v);
            }
            true
        } else {
            let base = slot * SLOT_INLINE;
            let n = self.counts[slot] as usize;
            let Ok(at) = self.inline[base..base + n].binary_search(&pe32) else {
                return false;
            };
            self.inline.copy_within(base + at + 1..base + n, base + at);
            self.counts[slot] -= 1;
            true
        }
    }

    /// PEs currently storing `slot`, ascending.
    pub fn holders_of(&self, slot: usize) -> &[u32] {
        match self.overflow.get(&(slot as u32)) {
            Some(ov) => ov,
            None => {
                let base = slot * SLOT_INLINE;
                &self.inline[base..base + self.counts[slot] as usize]
            }
        }
    }

    /// Slots `pe` currently stores, ascending — the reverse map that makes
    /// [`HolderIndex::drop_pe`] and scrub quarantine O(slots held).
    pub fn slots_of(&self, pe: usize) -> &[u32] {
        self.rev.get(pe).map_or(&[][..], |v| &v[..])
    }

    fn rev_insert(&mut self, pe: usize, slot: usize) {
        if pe >= self.rev.len() {
            self.rev.resize_with(pe + 1, Vec::new);
        }
        let v = &mut self.rev[pe];
        if let Err(at) = v.binary_search(&(slot as u32)) {
            v.insert(at, slot as u32);
        }
    }

    fn rev_remove(&mut self, pe: usize, slot: usize) {
        if let Ok(at) = self.rev[pe].binary_search(&(slot as u32)) {
            self.rev[pe].remove(at);
        }
    }

    /// From-scratch rebuild by scanning every PE store — the O(p · slices)
    /// reference the incremental maintenance is property-tested against.
    /// Slot boundaries come from `dist`, the *current* layout (one slot per
    /// distribution rank — `p'` after a rebalance, while stores stay
    /// indexed by original cluster rank): with balanced unequal slices a
    /// slot is no longer a fixed `blocks_per_pe` stride, so membership is
    /// resolved through [`Distribution::slice_of`].
    pub fn rebuild(stores: &[PeStore], dist: &Distribution) -> Self {
        let mut ix = HolderIndex::new(dist.world());
        for (pe, st) in stores.iter().enumerate() {
            for s in st.slices() {
                let first = dist.slice_of(s.range.start);
                let last = dist.slice_of(s.range.end - 1);
                for slot in first..=last {
                    ix.insert(slot, pe);
                }
            }
        }
        ix
    }
}

/// Content equality: same slot count and the same holder set per slot.
/// Deliberately representation-independent — whether a slot's holders
/// live inline or in overflow (or which stale inline entries linger past
/// `counts`) is a layout detail, not part of the index's meaning.
impl PartialEq for HolderIndex {
    fn eq(&self, other: &Self) -> bool {
        self.slots() == other.slots()
            && (0..self.slots()).all(|s| self.holders_of(s) == other.holders_of(s))
    }
}

impl Eq for HolderIndex {}

/// Verify the §IV-C memory formula for a fully submitted store set: every
/// PE holds exactly its `r` stored slices — `r · n/p` blocks in the
/// equal-slice layout, `Σ_k |stored_slice(pe, k)|` in general.
pub fn assert_memory_invariant(stores: &[PeStore], dist: &Distribution) {
    for (pe, st) in stores.iter().enumerate() {
        let expect: u64 =
            (0..dist.replicas()).map(|k| dist.stored_slice(pe, k).len()).sum();
        let blocks: u64 = st.slices().iter().map(|s| s.range.len()).sum();
        assert_eq!(blocks, expect, "PE {pe}: stores {blocks} blocks, expected {expect}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_from_slice() {
        let mut st = PeStore::new(4);
        let bytes: Vec<u8> = (0..32).collect();
        st.insert(BlockRange::new(8, 16), SliceBuf::Real(bytes));
        assert_eq!(st.read(8, 1), Some(&[0u8, 1, 2, 3][..]));
        assert_eq!(st.read(10, 2), Some(&[8u8, 9, 10, 11, 12, 13, 14, 15][..]));
        assert!(st.holds(8, 8));
        assert!(!st.holds(7, 2));
        assert!(!st.holds(15, 2));
        assert_eq!(st.resident_bytes(), 32);
    }

    #[test]
    #[should_panic(expected = "not stored")]
    fn read_missing_panics() {
        let st = PeStore::new(4);
        st.read(0, 1);
    }

    #[test]
    fn virtual_slice_counts_bytes() {
        let mut st = PeStore::new(64);
        st.insert(BlockRange::new(0, 100), SliceBuf::Virtual(6400));
        assert_eq!(st.read(50, 10), None);
        assert_eq!(st.resident_bytes(), 6400);
        assert!(st.holds(0, 100));
    }

    #[test]
    fn inserts_keep_slices_sorted_and_searchable() {
        // out-of-order inserts (as submit produces for k > 0 copies and
        // repair produces for re-created replicas) must stay binary-search
        // correct
        let mut st = PeStore::new(1);
        for (s, e) in [(40u64, 50u64), (0, 10), (20, 30), (70, 75)] {
            st.insert(BlockRange::new(s, e), SliceBuf::Virtual(e - s));
        }
        let starts: Vec<u64> = st.slices().iter().map(|s| s.range.start).collect();
        assert_eq!(starts, vec![0, 20, 40, 70]);
        for (s, e) in [(40u64, 50u64), (0, 10), (20, 30), (70, 75)] {
            assert!(st.holds(s, e - s));
            assert!(st.holds(s + 1, e - s - 1));
            assert!(!st.holds(s, e - s + 1)); // crosses the slice end
        }
        assert!(!st.holds(10, 5)); // gap
        assert!(!st.holds(15, 10)); // straddles a gap into a slice
        let f = st.find_slice(42, 3).unwrap();
        assert_eq!(f.range, BlockRange::new(40, 50));
        assert!(st.find_slice(42, 0).is_none());
        assert!(st.find_slice(30, 1).is_none());
    }

    #[test]
    fn write_updates_slice() {
        let mut st = PeStore::new(2);
        st.insert(BlockRange::new(0, 4), SliceBuf::Real(vec![0; 8]));
        st.write(1, &SliceBuf::Real(vec![9, 9, 7, 7]));
        assert_eq!(st.read(0, 4).unwrap(), &[0, 0, 9, 9, 7, 7, 0, 0]);
    }

    #[test]
    fn write_from_matches_write() {
        let mut a = PeStore::new(2);
        let mut b = PeStore::new(2);
        for st in [&mut a, &mut b] {
            st.insert(BlockRange::new(4, 8), SliceBuf::Real(vec![0; 8]));
        }
        a.write(5, &SliceBuf::Real(vec![9, 9, 7, 7]));
        b.write_from(5, &[9, 9, 7, 7]);
        assert_eq!(a.read(4, 4).unwrap(), b.read(4, 4).unwrap());
    }

    #[test]
    fn write_from_virtual_is_a_checked_noop() {
        let mut st = PeStore::new(4);
        st.insert(BlockRange::new(0, 8), SliceBuf::Virtual(32));
        st.write_from(2, &[1, 2, 3, 4]); // in range: fine, nothing stored
        assert_eq!(st.read(2, 1), None);
    }

    #[test]
    #[should_panic(expected = "not stored")]
    fn write_from_missing_panics() {
        let mut st = PeStore::new(4);
        st.insert(BlockRange::new(0, 8), SliceBuf::Virtual(32));
        st.write_from(6, &[0u8; 12]); // [6, 9) crosses the slice end
    }

    #[test]
    fn holder_index_insert_drop_rebuild() {
        // equal-slice reference layout: 4 slots of 8 blocks each
        let dist = Distribution::new_balanced(4, 32, 1, None, 0, 0).unwrap();
        let mut stores: Vec<PeStore> = (0..4).map(|_| PeStore::new(1)).collect();
        let mut ix = HolderIndex::new(4);
        for (pe, slot) in [(0usize, 0usize), (2, 0), (1, 1), (3, 3), (2, 3)] {
            let start = slot as u64 * 8;
            stores[pe].insert(BlockRange::new(start, start + 8), SliceBuf::Virtual(8));
            ix.insert(slot, pe);
        }
        ix.insert(0, 2); // idempotent
        assert_eq!(ix.holders_of(0), &[0, 2]);
        assert_eq!(ix.holders_of(1), &[1]);
        assert_eq!(ix.holders_of(2), &[] as &[u32]);
        assert_eq!(ix.holders_of(3), &[2, 3]);
        assert_eq!(ix, HolderIndex::rebuild(&stores, &dist));

        ix.drop_pe(2);
        stores[2].clear();
        assert_eq!(ix.holders_of(0), &[0]);
        assert_eq!(ix.holders_of(3), &[3]);
        assert_eq!(ix, HolderIndex::rebuild(&stores, &dist));
    }

    #[test]
    fn checksums_latched_on_insert_and_refreshed_by_writes() {
        let mut st = PeStore::new(4);
        st.insert(BlockRange::new(8, 16), SliceBuf::Real((0..32).collect()));
        assert_eq!(st.verify(8, 8), None);
        assert_eq!(st.corrupt_blocks(8, 8), 0);
        // a legitimate write keeps the sums in step with the content
        st.write_from(10, &[9, 9, 9, 9]);
        assert_eq!(st.verify(8, 8), None);
        st.write(12, &SliceBuf::Real(vec![7; 8]));
        assert_eq!(st.verify(8, 8), None);
        // virtual slices have nothing to verify
        let mut vt = PeStore::new(4);
        vt.insert(BlockRange::new(0, 8), SliceBuf::Virtual(32));
        assert_eq!(vt.verify(0, 8), None);
        assert_eq!(vt.corrupt_blocks(0, 8), 0);
        assert_eq!(vt.real_bytes(), 0);
        assert_eq!(vt.corrupt_bit_at(0, 3), None);
    }

    #[test]
    fn corrupt_bit_is_detected_and_located() {
        let mut st = PeStore::new(4);
        st.insert(BlockRange::new(8, 16), SliceBuf::Real((0..32).collect()));
        st.insert(BlockRange::new(40, 44), SliceBuf::Real(vec![5; 16]));
        assert_eq!(st.real_bytes(), 48);
        // offset 34 lands in the second slice (byte 2 -> block 40)
        assert_eq!(st.corrupt_bit_at(34, 0), Some(40));
        assert_eq!(st.verify(8, 8), None, "first slice untouched");
        assert_eq!(st.verify(40, 4), Some(40));
        assert_eq!(st.corrupt_blocks(40, 4), 1);
        // offset 13 -> first slice block 11 (byte 13, 4-byte blocks)
        assert_eq!(st.corrupt_bit_at(13, 7), Some(11));
        assert_eq!(st.verify(8, 8), Some(11));
        // flipping the same bit back restores a clean verify
        assert_eq!(st.corrupt_bit_at(13, 7), Some(11));
        assert_eq!(st.verify(8, 8), None);
        // past the resident payload: no-op
        assert_eq!(st.corrupt_bit_at(48, 0), None);
    }

    #[test]
    fn remove_quarantines_exact_slices_only() {
        let mut st = PeStore::new(1);
        st.insert(BlockRange::new(0, 10), SliceBuf::Real(vec![1; 10]));
        st.insert(BlockRange::new(20, 30), SliceBuf::Real(vec![2; 10]));
        assert!(!st.remove(0, 5), "sub-range of a stored slice is not removable");
        assert!(!st.remove(10, 5), "unstored range");
        assert!(st.remove(20, 10));
        assert!(!st.holds(20, 10));
        assert!(st.holds(0, 10), "other slices survive");
        assert_eq!(st.resident_bytes(), 10);
    }

    #[test]
    fn holder_index_single_slot_remove() {
        let mut ix = HolderIndex::new(3);
        for pe in [0usize, 2, 5] {
            ix.insert(1, pe);
            ix.insert(2, pe);
        }
        assert!(ix.remove(1, 2));
        assert!(!ix.remove(1, 2), "already gone");
        assert!(!ix.remove(0, 2), "never held");
        assert_eq!(ix.holders_of(1), &[0, 5]);
        assert_eq!(ix.holders_of(2), &[0, 2, 5], "other slots untouched");
    }

    #[test]
    fn holder_index_rebuild_with_unequal_slices() {
        // n = 30 over p = 4: slice lens 8, 8, 7, 7 (boundaries 0/8/16/23).
        let dist = Distribution::new_balanced(4, 30, 1, None, 0, 0).unwrap();
        let mut stores: Vec<PeStore> = (0..4).map(|_| PeStore::new(1)).collect();
        for (pe, slot) in [(0usize, 0usize), (1, 2), (3, 2), (2, 3)] {
            let range = dist.slice_range(slot);
            stores[pe].insert(range, SliceBuf::Virtual(range.len()));
        }
        let ix = HolderIndex::rebuild(&stores, &dist);
        assert_eq!(ix.holders_of(0), &[0]);
        assert_eq!(ix.holders_of(1), &[] as &[u32]);
        assert_eq!(ix.holders_of(2), &[1, 3]);
        assert_eq!(ix.holders_of(3), &[2]);
    }

    #[test]
    fn holder_index_overflow_spill_and_unspill() {
        let mut ix = HolderIndex::new(2);
        // 7 holders on slot 0: crosses the SLOT_INLINE boundary (spill)
        for pe in [9usize, 1, 5, 3, 7, 0, 11] {
            ix.insert(0, pe);
        }
        ix.insert(0, 5); // idempotent while spilled
        assert_eq!(ix.holders_of(0), &[0, 1, 3, 5, 7, 9, 11]);
        assert_eq!(ix.holders_of(1), &[] as &[u32]);
        // reverse map tracks every holder (including past the slot count)
        for pe in [0usize, 1, 3, 5, 7, 9, 11] {
            assert_eq!(ix.slots_of(pe), &[0], "pe {pe}");
        }
        assert_eq!(ix.slots_of(2), &[] as &[u32]);
        assert_eq!(ix.slots_of(999), &[] as &[u32], "past the reverse map");
        assert!(!ix.remove(0, 2), "never held while spilled");
        // shrink back below the inline capacity: content (and equality
        // with a never-spilled index) is unaffected by the spill history
        for pe in [9usize, 1, 7] {
            assert!(ix.remove(0, pe));
        }
        assert_eq!(ix.holders_of(0), &[0, 3, 5, 11]);
        let mut fresh = HolderIndex::new(2);
        for pe in [0usize, 3, 5, 11] {
            fresh.insert(0, pe);
        }
        assert_eq!(ix, fresh);
        // drop_pe goes through the reverse map; both directions clear
        ix.drop_pe(5);
        assert_eq!(ix.holders_of(0), &[0, 3, 11]);
        assert_eq!(ix.slots_of(5), &[] as &[u32]);
        ix.drop_pe(999); // past the reverse map: no-op
        assert_eq!(ix.holders_of(0), &[0, 3, 11]);
    }
}
