//! # ReStore — in-memory replicated storage for rapid recovery
//!
//! A Rust + JAX/Pallas reproduction of *ReStore: In-Memory REplicated
//! STORagE for Rapid Recovery in Fault-Tolerant Algorithms* (Hübner, Hespe,
//! Sanders, Stamatakis — FTXS @ SC 2022).
//!
//! The crate is organised in the paper's own layers:
//!
//! * [`simnet`] — the fault-tolerant cluster substrate the paper runs on
//!   (MPI + ULFM on SuperMUC-NG in the paper; a simulated cluster with an
//!   exact-schedule α-β transport model here — see `DESIGN.md §1`).
//! * [`restore`] — the paper's contribution: replica placement `L(x,k)`,
//!   permutation ranges, the `submit`/`load` sparse all-to-all paths, the
//!   irrecoverable-data-loss (IDL) analysis of §IV-D, the §IV-E replica
//!   repair distributions, and the §V **multi-dataset registry** — one
//!   `Dataset` per application datatype (independent `n`/`r`/`b`/seed)
//!   with fused cross-dataset recovery (`ReStore::load_many`) and shrink
//!   handshakes (`ReStore::rebalance_or_acknowledge_all`). The
//!   single-dataset calls below are a facade over dataset 0.
//! * [`pfs`] — the parallel-file-system baseline every disk-based
//!   checkpointing library bottoms out in (Fig 6/7 comparisons).
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs on the recovery path.
//! * [`apps`] — the paper's fault-tolerant applications: k-means (§VI-C,
//!   Fig 5), an FT-RAxML-NG-style phylogenetic proxy (Fig 6), and PageRank.
//!
//! ## Quickstart
//!
//! ```no_run
//! use restore::config::RestoreConfig;
//! use restore::simnet::cluster::Cluster;
//! use restore::restore::ReStore;
//!
//! // 16 PEs, 1 MiB of 64 B blocks per PE, 4 replicas, 256 KiB perm ranges.
//! let cfg = RestoreConfig::builder(16, 64, 16 * 1024)
//!     .replicas(4)
//!     .perm_range_bytes(Some(256 * 1024))
//!     .build()
//!     .unwrap();
//! let mut cluster = Cluster::new_execution(16, 48);
//! let mut store = ReStore::new(cfg, &cluster).unwrap();
//!
//! // Every PE submits its local shard once...
//! let shards: Vec<Vec<u8>> = (0..16u8).map(|i| vec![i; 1024 * 1024]).collect();
//! store.submit(&mut cluster, &shards).unwrap();
//!
//! // ...a PE fails...
//! cluster.kill(&[3]);
//!
//! // ...and the survivors reload the lost shard, scattered across them.
//! let requests = restore::restore::load::scatter_requests(&store, &cluster, &[3]);
//! let loaded = store.load(&mut cluster, &requests).unwrap();
//! assert!(loaded.cost.sim_time_s < 0.1);
//! ```

pub mod apps;
pub mod config;
pub mod error;
pub mod metrics;
pub mod pfs;
pub mod restore;
pub mod runtime;
pub mod simnet;
pub mod util;

pub use error::{Error, Result};
