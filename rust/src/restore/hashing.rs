//! Hashing utilities shared by the permutation and repair distributions.
//!
//! The paper's Appendix builds its replica-repair probing sequences from
//! "fast-to-compute hash functions that avoid collisions" plus coprimality
//! checks against the prime factors of `p` (Distribution A) and a Feistel
//! network with cycle walking (Distribution B). This module provides those
//! primitives.

/// SplitMix64 — a fast, well-mixed 64-bit hash (the paper's `f` / `h_s`).
/// The seed parametrizes the family, `h_s(x) = splitmix64(x ^ mix(s))`.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Seeded hash family.
#[inline]
pub fn seeded_hash(seed: u64, x: u64) -> u64 {
    splitmix64(x ^ splitmix64(seed))
}

/// Prime factorization by trial division (run once per program start on the
/// node count `p` — the paper's Appendix; Erdős–Kac: ~3 distinct factors
/// for p < 10^9, so this is trivially fast for any realistic node count).
pub fn prime_factors(mut n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 2u64;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// Is `x` coprime to the number whose distinct prime factors are `factors`?
/// (The Appendix's "< m·1.65 divisions" check.)
#[inline]
pub fn coprime_to_factors(x: u64, factors: &[u64]) -> bool {
    if x == 0 {
        return false;
    }
    factors.iter().all(|&f| x % f != 0)
}

/// GCD (for tests / the slow path).
pub fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_spreads() {
        assert_eq!(splitmix64(1), splitmix64(1));
        assert_ne!(splitmix64(1), splitmix64(2));
        // Low bits must vary too (used mod p).
        let lows: std::collections::HashSet<u64> =
            (0..1000u64).map(|x| splitmix64(x) % 64).collect();
        assert!(lows.len() > 32);
    }

    #[test]
    fn factors_of_500() {
        // Paper's Appendix example: p = 500 has prime factors 2 and 5.
        assert_eq!(prime_factors(500), vec![2, 5]);
        assert_eq!(prime_factors(1), Vec::<u64>::new());
        assert_eq!(prime_factors(97), vec![97]);
        assert_eq!(prime_factors(24576), vec![2, 3]);
    }

    #[test]
    fn coprimality_matches_gcd() {
        let p = 500u64;
        let fs = prime_factors(p);
        for x in 1..200u64 {
            assert_eq!(coprime_to_factors(x, &fs), gcd(x, p) == 1, "x={x}");
        }
        assert!(!coprime_to_factors(0, &fs));
    }

    #[test]
    fn appendix_example_coprimality() {
        // h_s(x)=3 coprime to 500; h_s(y)=20 not; h_s'(y)=7 coprime.
        let fs = prime_factors(500);
        assert!(coprime_to_factors(3, &fs));
        assert!(!coprime_to_factors(20, &fs));
        assert!(coprime_to_factors(7, &fs));
    }
}
