//! Configuration system: typed configs with paper defaults, TOML loading,
//! and CLI override hooks (see `main.rs`).
//!
//! Paper defaults (§VI-A/§VI-B): 64 B blocks, 16 MiB per PE, `r = 4`
//! replicas, 256 KiB permutation ranges, 48 PEs per node, OmniPath-class
//! 100 Gbit/s interconnect.

mod toml_file;

pub use toml_file::{AppConfig, AppKind, ExperimentFile};

use crate::error::{Error, Result};

/// Paper default: block size in bytes (§VI-B2).
pub const DEFAULT_BLOCK_SIZE: usize = 64;
/// Paper default: checkpoint payload per PE (§VI-B2).
pub const DEFAULT_BYTES_PER_PE: usize = 16 * 1024 * 1024;
/// Paper default: replication level chosen in §VI-B1.
pub const DEFAULT_REPLICAS: usize = 4;
/// Paper default: permutation range size chosen in §VI-B2.
pub const DEFAULT_PERM_RANGE_BYTES: usize = 256 * 1024;
/// SuperMUC-NG: 48 cores (PEs) per node (§VI-A).
pub const DEFAULT_PES_PER_NODE: usize = 48;

/// How the load path picks the serving PE among surviving replica holders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServerSelection {
    /// Paper policy (§IV-A): a seeded-random surviving holder, with
    /// consecutive blocks served by the same PE where possible.
    #[default]
    Random,
    /// Greedy least-loaded holder (ablation).
    LeastLoaded,
    /// Always the lowest-index surviving copy (ablation; worst bottleneck).
    Primary,
}

/// Configuration of one `ReStore` instance.
#[derive(Debug, Clone)]
pub struct RestoreConfig {
    /// World size `p` at submit time.
    pub world: usize,
    /// Serialized block size in bytes.
    pub block_size: usize,
    /// Number of data blocks each PE submits (`n = world * blocks_per_pe`).
    pub blocks_per_pe: usize,
    /// Replication level `r` (§IV-A); must divide `world`.
    pub replicas: usize,
    /// Blocks per permutation range `s_pr` (§IV-B); `None` disables the ID
    /// permutation (recommended by the paper for load-all recovery).
    pub perm_range_blocks: Option<usize>,
    /// Seed for the range permutation and server selection.
    pub seed: u64,
    /// Serving-PE selection policy.
    pub server_selection: ServerSelection,
    /// Constant rank offset added to every copy's placement:
    /// `L(x,k) = ⌊π(x)p/n⌋ + k·p/r + offset (mod p)`. With `r = 1` an
    /// offset of 1 stores the single copy on the *neighbouring* rank (the
    /// partner-copy scheme of Fenix, §VI-D.2) instead of the submitting
    /// rank itself. 0 (paper default) reproduces §IV-A exactly.
    pub placement_offset: usize,
}

impl RestoreConfig {
    /// Start building a config for `world` PEs submitting `blocks_per_pe`
    /// blocks of `block_size` bytes each.
    pub fn builder(world: usize, block_size: usize, blocks_per_pe: usize) -> RestoreConfigBuilder {
        RestoreConfigBuilder {
            cfg: RestoreConfig {
                world,
                block_size,
                blocks_per_pe,
                replicas: DEFAULT_REPLICAS,
                perm_range_blocks: None,
                seed: 0x5e5705e,
                server_selection: ServerSelection::default(),
                placement_offset: 0,
            },
        }
    }

    /// Paper-default config: 16 MiB of 64 B blocks per PE, r=4, 256 KiB
    /// permutation ranges.
    pub fn paper_default(world: usize) -> Result<Self> {
        Self::builder(world, DEFAULT_BLOCK_SIZE, DEFAULT_BYTES_PER_PE / DEFAULT_BLOCK_SIZE)
            .replicas(DEFAULT_REPLICAS)
            .perm_range_bytes(Some(DEFAULT_PERM_RANGE_BYTES))
            .build()
    }

    /// Total number of blocks `n`.
    pub fn n_blocks(&self) -> u64 {
        self.world as u64 * self.blocks_per_pe as u64
    }

    /// Number of permutation ranges per PE shard (1 if permutation is off —
    /// the whole shard is a single contiguous unit then).
    pub fn ranges_per_pe(&self) -> usize {
        match self.perm_range_blocks {
            Some(s) => self.blocks_per_pe / s,
            None => 1,
        }
    }

    /// Bytes each PE stores for the replicated storage: `r * n/p` blocks
    /// (§IV-C memory analysis).
    pub fn replica_bytes_per_pe(&self) -> usize {
        self.replicas * self.blocks_per_pe * self.block_size
    }

    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Config(m));
        if self.world == 0 || self.block_size == 0 || self.blocks_per_pe == 0 {
            return err("world, block_size, blocks_per_pe must be positive".into());
        }
        if self.replicas == 0 || self.replicas > self.world {
            return err(format!(
                "replicas r={} must be in [1, world={}]",
                self.replicas, self.world
            ));
        }
        // r | p: the §IV-D group analysis and the copy-offset placement
        // k*p/r both assume it (reasonable on even-cored dual-socket nodes).
        if self.world % self.replicas != 0 {
            return err(format!(
                "replicas r={} must divide world p={}",
                self.replicas, self.world
            ));
        }
        if let Some(s) = self.perm_range_blocks {
            if s == 0 || self.blocks_per_pe % s != 0 {
                return err(format!(
                    "perm range of {s} blocks must divide blocks_per_pe={}",
                    self.blocks_per_pe
                ));
            }
        }
        Ok(())
    }
}

/// Builder for [`RestoreConfig`].
pub struct RestoreConfigBuilder {
    cfg: RestoreConfig,
}

impl RestoreConfigBuilder {
    pub fn replicas(mut self, r: usize) -> Self {
        self.cfg.replicas = r;
        self
    }

    /// Set the permutation range size in *blocks*.
    pub fn perm_range_blocks(mut self, s: Option<usize>) -> Self {
        self.cfg.perm_range_blocks = s;
        self
    }

    /// Set the permutation range size in *bytes* (must be a multiple of the
    /// block size); the paper quotes range sizes in bytes (Fig 4a).
    pub fn perm_range_bytes(mut self, bytes: Option<usize>) -> Self {
        self.cfg.perm_range_blocks = bytes.map(|b| b / self.cfg.block_size);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn server_selection(mut self, s: ServerSelection) -> Self {
        self.cfg.server_selection = s;
        self
    }

    /// See [`RestoreConfig::placement_offset`].
    pub fn placement_offset(mut self, o: usize) -> Self {
        self.cfg.placement_offset = o;
        self
    }

    pub fn build(self) -> Result<RestoreConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// Network model parameters (DESIGN.md §1: α-β with a shared per-node NIC).
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    /// Per-message latency in seconds (OmniPath-class: ~2 µs).
    pub alpha_s: f64,
    /// Per-node NIC bandwidth, bytes/s (100 Gbit/s = 12.5 GB/s). Send and
    /// receive share it (half-duplex effective, which calibrates to the
    /// paper's §VI-D.2 submit numbers).
    pub node_bw_bytes_per_s: f64,
    /// Per-PE in-memory copy bandwidth, bytes/s (local (de)serialization).
    pub pe_mem_bw_bytes_per_s: f64,
    /// PEs per node (share the NIC).
    pub pes_per_node: usize,
    /// Fragmentation/congestion coefficient: the effective NIC bandwidth
    /// of a node handling an average of `m` messages per PE degrades by
    /// `1 + γ·ln(1 + m)` (packet interleaving, MPI matching, rendezvous
    /// round-trips). Calibrated so the §VI-D.2 submit ratios and the
    /// Fig 4b dense-pattern slowdowns match the paper (EXPERIMENTS.md
    /// §Calibration). 0 disables the term (pure α-β).
    pub frag_gamma: f64,
    /// Per-fragment handling cost in seconds: every non-contiguous piece
    /// a PE packs (send side) or unpacks (receive side) costs a fixed CPU
    /// overhead (scattered 64 B memcpys, MPI datatype/descriptor work).
    /// This is what blows up the left edge of Fig 4a: tiny permutation
    /// ranges fragment every message into thousands of pieces.
    pub fragment_cost_s: f64,
    /// Effective global-traffic efficiency factor: phases moving large
    /// total volume are bounded by `total_bytes / (node_bw·nodes/this)`.
    /// Captures fat-tree pruning (SuperMUC-NG prunes 1:4 between islands)
    /// plus the routing losses of real global all-to-alls; calibrated to
    /// the paper's §VI-D.2 submit times (2.0). 0 disables the term.
    pub bisection_oversubscription: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            alpha_s: 2e-6,
            node_bw_bytes_per_s: 12.5e9,
            pe_mem_bw_bytes_per_s: 8e9,
            pes_per_node: DEFAULT_PES_PER_NODE,
            frag_gamma: 0.12,
            fragment_cost_s: 1.0e-6,
            bisection_oversubscription: 2.0,
        }
    }
}

/// Parallel-file-system model parameters (Fig 6/7 baseline; Lustre-class).
#[derive(Debug, Clone)]
pub struct PfsConfig {
    /// Aggregate read bandwidth of the file system, bytes/s.
    pub aggregate_bw_bytes_per_s: f64,
    /// Per-client achievable stream bandwidth, bytes/s.
    pub per_client_bw_bytes_per_s: f64,
    /// Metadata/open latency per file open, seconds.
    pub open_latency_s: f64,
    /// Number of object storage targets (stripes) contended for.
    pub osts: usize,
    /// Node page-cache read bandwidth for the "cached" series of Fig 6.
    pub page_cache_bw_bytes_per_s: f64,
}

impl Default for PfsConfig {
    fn default() -> Self {
        PfsConfig {
            aggregate_bw_bytes_per_s: 50e9,
            per_client_bw_bytes_per_s: 1.2e9,
            open_latency_s: 2e-3,
            osts: 256,
            page_cache_bw_bytes_per_s: 6e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_valid() {
        let cfg = RestoreConfig::paper_default(48).unwrap();
        assert_eq!(cfg.block_size, 64);
        assert_eq!(cfg.blocks_per_pe, 262_144);
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.perm_range_blocks, Some(4096));
        assert_eq!(cfg.ranges_per_pe(), 64); // 16 MiB / 256 KiB (§VI-B2)
        assert_eq!(cfg.replica_bytes_per_pe(), 4 * 16 * 1024 * 1024);
    }

    #[test]
    fn replicas_must_divide_world() {
        assert!(RestoreConfig::builder(10, 64, 1024).replicas(4).build().is_err());
        assert!(RestoreConfig::builder(12, 64, 1024).replicas(4).build().is_ok());
    }

    #[test]
    fn perm_range_must_divide_shard() {
        let b = |s| {
            RestoreConfig::builder(4, 64, 1024)
                .replicas(2)
                .perm_range_blocks(Some(s))
                .build()
        };
        assert!(b(100).is_err());
        assert!(b(128).is_ok());
    }

    #[test]
    fn perm_range_bytes_converts() {
        let cfg = RestoreConfig::builder(4, 64, 1024)
            .replicas(2)
            .perm_range_bytes(Some(8192))
            .build()
            .unwrap();
        assert_eq!(cfg.perm_range_blocks, Some(128));
    }

    #[test]
    fn zero_sizes_rejected() {
        assert!(RestoreConfig::builder(0, 64, 1).build().is_err());
        assert!(RestoreConfig::builder(4, 0, 1).build().is_err());
        assert!(RestoreConfig::builder(4, 64, 0).build().is_err());
    }
}
