//! §IV-E scenario: restoring the replication level after failures.
//!
//! The paper proposes (as future work) re-creating lost replicas on the
//! next alive PE of a per-block probing sequence, leaving all surviving
//! replicas in place. This example drives both Appendix constructions
//! (Distribution A: double hashing with coprime steps; Distribution B:
//! Feistel walk) through a failure storm and shows that the replication
//! level stays at r while only O(lost replicas) data moves.
//!
//! Run with: `cargo run --release --example replica_repair`

use restore::metrics::fmt_time;
use restore::restore::repair::{plan_repairs, ProbeSequences, RepairScheme};
use restore::simnet::cluster::Cluster;
use restore::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let p = 64usize;
    let r = 4usize;
    let units: Vec<(u64, u64, u64)> =
        (0..256u64).map(|u| (u, u * 4096, 4096)).collect(); // 256 KiB ranges
    let unit_bytes = 4096 * 64u64;

    for scheme in [RepairScheme::DoubleHashing, RepairScheme::FeistelWalk] {
        println!("=== {scheme:?} ===");
        let seqs = ProbeSequences::new(p, 0xC0DE, scheme);
        let mut cluster = Cluster::new_execution(p, 8);
        let mut rng = Rng::seed_from_u64(9);

        // deterministic §IV-A first-r placement for each unit
        let det = |u: u64| move |k: usize| ((u as usize) + k * (p / r)) % p;

        let mut total_moved = 0u64;
        let mut total_transfers = 0usize;
        for wave in 0..6 {
            // kill 4 random PEs per wave
            let survivors = cluster.survivors();
            let dead = restore::simnet::failure::uniform_kills(&mut rng, &survivors, 4);
            let alive_before: Vec<bool> = (0..p).map(|pe| cluster.is_alive(pe)).collect();
            cluster.kill(&dead);
            let alive_after: Vec<bool> = (0..p).map(|pe| cluster.is_alive(pe)).collect();

            let old = |u: u64| seqs.replica_homes(u, r, |pe| alive_before[pe], det(u));
            let new = |u: u64| seqs.replica_homes(u, r, |pe| alive_after[pe], det(u));
            let plan = plan_repairs(&units, old, new);

            // apply: charge the transfers to the simulated network
            let t0 = cluster.now();
            let cost = cluster
                .charge_phase(plan.iter().map(|t| (t.src, t.dst, unit_bytes)))?;
            total_moved += cost.total_bytes;
            total_transfers += plan.len();

            // verify the invariant: every unit has exactly r alive homes
            for &(u, _, _) in &units {
                let homes = new(u);
                assert_eq!(homes.len(), r, "unit {u} lost replication after wave {wave}");
                for h in &homes {
                    assert!(cluster.is_alive(*h));
                }
            }
            println!(
                "wave {wave}: killed {dead:?} -> {} transfers, {} moved, {} sim time",
                plan.len(),
                human(cost.total_bytes),
                fmt_time(cluster.now() - t0)
            );
        }
        let stored = units.len() as u64 * r as u64 * unit_bytes;
        println!(
            "after 24 failures: replication level still {r}; moved {} total over 6 repairs \
             ({:.1} % of the {} stored)\n",
            human(total_moved),
            100.0 * total_moved as f64 / stored as f64,
            human(stored),
        );
        let _ = total_transfers;
    }

    // The Appendix's coprime-retry estimate
    let seqs = ProbeSequences::new(24576, 1, RepairScheme::DoubleHashing);
    for x in 0..10_000u64 {
        seqs.probe(x, 1);
    }
    let avg = seqs.seed_trials.get() as f64 / seqs.seed_calls.get() as f64;
    println!(
        "double-hashing seed retries (p=24576, factors 2,3): {avg:.2} per block \
         (Appendix predicts ~{:.2})",
        // P(coprime to 2^a*3) = 1/2 * 2/3 = 1/3 -> E = 3
        3.0
    );
    Ok(())
}

fn human(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    }
}
