//! §IV-B shrinking recovery, end to end: agree → shrink → rebalance → load.
//!
//! The paper: "we also support shrinking recovery instead of recovery using
//! spare compute nodes" — on *whatever* resources survive. This example
//! drives the full story the balanced unequal-slice rebalance enables,
//! deliberately through survivor counts that do NOT divide the block
//! space (the kill waves real clusters actually produce):
//!
//! 1. a failure wave kills 19 of 64 PEs (at most 2 per §IV-D group, so no
//!    data is lost) — p' = 45 divides neither n nor r;
//! 2. the survivors run the ULFM-style `agree` + `shrink` — the shrink
//!    bumps the communicator epoch, and the store refuses to route until it
//!    adopts the new world (demonstrated live);
//! 3. `ReStore::rebalance` rewrites the balanced §IV-A layout over the
//!    `p'` survivors (⌊n/p'⌋/⌈n/p'⌉-block slices, closed-form
//!    boundaries), migrating only the intervals whose holder set changed;
//! 4. recovered loads verify bit-exactness, and `restore::idl` quantifies
//!    the payoff: before the rebalance slots are down to 2–3 copies,
//!    afterwards all slots are back at r = 4 on the new world (the
//!    fresh-replication level).
//!
//! A second wave repeats the cycle at p' = 45 → p'' = 23, showing that
//! rebalances chain through arbitrary worlds. A final wave then kills PEs
//! *without* shrinking and runs §IV-E probing-sequence replica repair
//! inside the rebalanced world — the two recovery mechanisms compose:
//! rebalance after a shrink (now feasible for every p' ≥ r), repair in
//! place when the application keeps the communicator.
//!
//! NEW with the multi-dataset registry (§V): a second dataset — 1 KiB/PE
//! of "model state" with its own r = 2 and 16 B blocks — rides every wave.
//! One fused `rebalance_or_acknowledge_all` adopts each shrink for BOTH
//! datasets under the single epoch bump (their migration all-to-alls
//! merged into one phase), and both datasets' lost shards reload
//! bit-exactly afterwards.
//!
//! Run with: `cargo run --release --example replica_repair`

use restore::config::RestoreConfig;
use restore::error::Error;
use restore::metrics::fmt_time;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::idl;
use restore::restore::repair::RepairScheme;
use restore::restore::{Dataset, DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;

const P: usize = 64;
const R: usize = 4;
const BPP: u64 = 256; // blocks per PE at p = 64
const BS: usize = 8;
/// Second dataset: model state — its own replication level and block size.
const R2: usize = 2;
const BPP2: u64 = 64;
const BS2: usize = 16;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = RestoreConfig::builder(P, BS, BPP as usize)
        .replicas(R)
        .perm_range_blocks(Some(64))
        .build()?;
    let model_cfg = RestoreConfig::builder(P, BS2, BPP2 as usize).replicas(R2).build()?;
    let mut cluster = Cluster::new_execution(P, 8);
    let mut store = ReStore::new(cfg, &cluster)?;
    let model = store.create_dataset(model_cfg, &cluster)?;
    let shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP as usize * BS).map(|i| (pe * 41 + i * 3) as u8).collect())
        .collect();
    let model_shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP2 as usize * BS2).map(|i| (pe * 13 + i * 7) as u8).collect())
        .collect();
    store.submit(&mut cluster, &shards)?;
    store.dataset_mut(model)?.submit(&mut cluster, &model_shards)?;
    println!(
        "submitted {} PEs x {} KiB (r = {R}) + {} B model state (r = {R2}), epoch {}",
        P,
        BPP as usize * BS / 1024,
        BPP2 as usize * BS2,
        store.epoch()
    );

    // --- wave 1: 64 -> 45 (non-dividing) ------------------------------------
    // Kill ranks 0..19: every §IV-D group (stride p/r = 16) loses at most
    // 2 of its 4 members — recoverable (the model dataset's r = 2 groups
    // sit at stride 32, so they lose at most 1 of 2). p' = 45 is the
    // layout the old equal-slice geometry had to refuse (45 ∤ n, 4 ∤ 45);
    // the balanced unequal slices (364/365 blocks) carry it.
    let wave1: Vec<usize> = (0..19).collect();
    run_wave(&mut cluster, &mut store, &shards, &model_shards, &wave1, "wave 1 (64 -> 45)")?;

    // --- wave 2: 45 -> 23 (non-dividing, chained) ---------------------------
    // Kill the 22 lowest survivors (= new ranks 0..22): holders sit at
    // stride ⌊45/4⌋ = 11 (model: ⌊45/2⌋ = 22) in the rebalanced world, so
    // a window of 22 consecutive ranks takes at most 2 of any slot's 4
    // holders (at most 1 of the model's 2).
    let wave2: Vec<usize> = cluster.survivors()[..22].to_vec();
    run_wave(&mut cluster, &mut store, &shards, &model_shards, &wave2, "wave 2 (45 -> 23)")?;

    // --- wave 3: §IV-E repair inside the rebalanced world -------------------
    // Two more PEs die. The application *could* shrink and rebalance again
    // (21 >= r = 4 survivors admit the balanced layout) — here it instead
    // keeps the communicator and re-creates the lost replicas on
    // probing-sequence homes (Appendix Distribution A), leaving every
    // surviving replica in place. Repair composes with the rebalanced
    // distribution: planning runs in the compact p'' = 23 rank space and
    // translates to cluster ranks at the store/network boundary.
    println!("\n=== wave 3: 2 PEs die; repair instead of shrink ===");
    let extra: Vec<usize> = cluster.survivors()[..2].to_vec();
    cluster.kill(&extra);
    let degraded = count_slots_below_r(store.dataset(DatasetId::FIRST)?, &cluster, R);
    let rep = store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing)?;
    let rep2 = store
        .dataset_mut(DatasetId(1))?
        .repair_replicas(&mut cluster, RepairScheme::DoubleHashing)?;
    println!(
        "{degraded} slots were below r = {R} copies; repair moved {} + {} slices \
         ({} unrepairable), {} sim time",
        rep.transfers,
        rep2.transfers,
        rep.unrepairable + rep2.unrepairable,
        fmt_time(rep.cost.sim_time_s + rep2.cost.sim_time_s)
    );
    assert_eq!(
        count_slots_below_r(store.dataset(DatasetId::FIRST)?, &cluster, R),
        0,
        "repair must restore r copies"
    );
    assert_eq!(
        count_slots_below_r(store.dataset(DatasetId(1))?, &cluster, R2),
        0,
        "repair must restore the model dataset's r copies too"
    );
    println!("every slot of both datasets back at full alive replication");

    println!("\nall waves recovered bit-exactly; layout epoch {}", store.epoch());
    Ok(())
}

/// Slots of a dataset's current layout with fewer than `r` alive holders.
fn count_slots_below_r(ds: &Dataset, cluster: &Cluster, r: usize) -> usize {
    (0..ds.distribution().world())
        .filter(|&slot| {
            let alive = ds
                .holder_index()
                .holders_of(slot)
                .iter()
                .filter(|&&pe| cluster.is_alive(pe as usize))
                .count();
            alive < r
        })
        .count()
}

fn run_wave(
    cluster: &mut Cluster,
    store: &mut ReStore,
    shards: &[Vec<u8>],
    model_shards: &[Vec<u8>],
    kills: &[usize],
    tag: &str,
) -> Result<(), Box<dyn std::error::Error>> {
    println!("\n=== {tag}: killing {} PEs ===", kills.len());
    cluster.kill(kills);
    let (failed, c_agree) = ulfm::agree(cluster);
    let (map, c_shrink) = ulfm::shrink(cluster);
    let p_new = map.new_world() as u64;
    println!(
        "agree found {} dead ({}), shrink -> {} ranks ({}), cluster epoch {}",
        failed.len(),
        fmt_time(c_agree.sim_time_s),
        p_new,
        fmt_time(c_shrink.sim_time_s),
        cluster.epoch()
    );

    // The store still addresses the old world: routing is refused until
    // the shrink is adopted.
    let probe = vec![LoadRequest {
        pe: cluster.survivors()[0],
        ranges: RangeSet::new(vec![BlockRange::new(0, 8)]),
    }];
    match store.load(cluster, &probe) {
        Err(Error::StaleEpoch { store_epoch, cluster_epoch }) => println!(
            "load before rebalance refused: store epoch {store_epoch} vs cluster {cluster_epoch}"
        ),
        other => return Err(format!("expected StaleEpoch, got {other:?}").into()),
    }

    // IDL risk for the NEXT failures, before the rebalance: the hardest-hit
    // slots are down to fewer surviving copies spread over p' PEs.
    let alive_copies = (0..store.distribution().world())
        .map(|slot| {
            store
                .holder_index()
                .holders_of(slot)
                .iter()
                .filter(|&&pe| cluster.is_alive(pe as usize))
                .count() as u64
        })
        .min()
        .unwrap();
    println!("surviving copies on the hardest-hit slot before rebalance: {alive_copies}");
    print!("P(IDL | f more failures) before:");
    for f in [2u64, 4, 8] {
        print!("  f={f}: {:.2e}", idl::p_idl_leq(p_new, alive_copies, f));
    }
    println!();

    // Fused rebalance: fresh §IV-A layouts for BOTH datasets over the
    // survivors, minimal migrations merged into one sparse all-to-all,
    // one epoch adoption.
    let t0 = cluster.now();
    let outcomes = store.rebalance_or_acknowledge_all(cluster, &map)?;
    let report = outcomes[0].as_ref().expect("point dataset must rebalance");
    let report2 = outcomes[1].as_ref().expect("model dataset must rebalance");
    assert_eq!(store.epoch(), cluster.epoch());
    assert_eq!(store.dataset(DatasetId(1))?.epoch(), cluster.epoch());
    // total replicated volume is r·n·bs regardless of how p' slices it
    let stored: u64 = R as u64 * store.distribution().n_blocks() * BS as u64;
    let dist = store.distribution();
    println!(
        "balanced slices at p' = {p_new}: {} x {} blocks + {} x {} blocks",
        dist.n_blocks() % p_new,
        dist.max_slice_blocks(),
        p_new - dist.n_blocks() % p_new,
        dist.n_blocks() / p_new,
    );
    println!(
        "fused rebalance: {} + {} transfers moved {} ({:.1} % of the {} stored) + {} model, \
         kept {} local, {}",
        report.transfers,
        report2.transfers,
        human(report.migrated_bytes),
        100.0 * report.migrated_bytes as f64 / stored as f64,
        human(stored),
        human(report2.migrated_bytes),
        human(report.kept_bytes + report2.kept_bytes),
        fmt_time(cluster.now() - t0)
    );

    // ...and the IDL probability is back at the fresh-r level.
    print!("P(IDL | f more failures) after: ");
    for f in [2u64, 4, 8] {
        print!("  f={f}: {:.2e}", idl::p_idl_leq(p_new, R as u64, f));
    }
    println!();

    // Verify: scatter-load the killed PEs' original shards over the
    // survivors and check every byte.
    let survivors = cluster.survivors();
    let reqs: Vec<LoadRequest> = kills
        .iter()
        .enumerate()
        .map(|(i, &dead)| LoadRequest {
            pe: survivors[i % survivors.len()],
            ranges: RangeSet::new(vec![BlockRange::new(
                dead as u64 * BPP,
                (dead as u64 + 1) * BPP,
            )]),
        })
        .collect();
    let out = store.load(cluster, &reqs)?;
    let mut verified = 0usize;
    for (req, shard) in reqs.iter().zip(&out.shards) {
        let bytes = shard.bytes.as_ref().expect("execution mode");
        let mut off = 0usize;
        for range in req.ranges.ranges() {
            for x in range.start..range.end {
                let pe = (x / BPP) as usize;
                let boff = ((x % BPP) as usize) * BS;
                assert_eq!(&bytes[off..off + BS], &shards[pe][boff..boff + BS]);
                off += BS;
            }
        }
        verified += bytes.len();
    }
    println!(
        "reloaded the {} lost shards scattered over {} survivors in {} — {} verified bit-exact",
        kills.len(),
        survivors.len(),
        fmt_time(out.cost.sim_time_s),
        human(verified as u64)
    );

    // ...and the model dataset reloads its lost shards bit-exactly in its
    // own rebalanced layout, through the dataset handle.
    let model_reqs: Vec<LoadRequest> = kills
        .iter()
        .enumerate()
        .map(|(i, &dead)| LoadRequest {
            pe: survivors[i % survivors.len()],
            ranges: RangeSet::new(vec![BlockRange::new(
                dead as u64 * BPP2,
                (dead as u64 + 1) * BPP2,
            )]),
        })
        .collect();
    let model_out = store.dataset_mut(DatasetId(1))?.load(cluster, &model_reqs)?;
    for (req, shard) in model_reqs.iter().zip(&model_out.shards) {
        let bytes = shard.bytes.as_ref().expect("execution mode");
        let mut off = 0usize;
        for range in req.ranges.ranges() {
            for x in range.start..range.end {
                let pe = (x / BPP2) as usize;
                let boff = ((x % BPP2) as usize) * BS2;
                assert_eq!(&bytes[off..off + BS2], &model_shards[pe][boff..boff + BS2]);
                off += BS2;
            }
        }
    }
    println!("model dataset: {} lost shards verified bit-exact", kills.len());
    Ok(())
}

fn human(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}
