"""AOT bridge: lower the L2 models to HLO *text* artifacts for Rust/PJRT.

HLO text (not `lowered.compile().serialize()` / serialized HloModuleProto) is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the `xla` rust crate) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Emits one `<name>.hlo.txt` per model variant plus `manifest.json` describing
every variant's argument/result shapes for the Rust runtime's registry.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    """Every artifact: name -> (jitted fn, example arg specs, result names).

    Shapes follow the paper's configuration (DESIGN.md §5):
      k-means: 65 536 points x 32 dims per PE (16 MiB at f64 in the paper;
      our compute artifact is f32 — the ReStore payload stays 16 MiB), 20
      centers. The *_small variants back fast tests and examples.
    """
    out = {}

    def kmeans(n, d, k, tile):
        fn = functools.partial(model.kmeans_step, tile=tile)
        return (
            jax.jit(fn),
            (spec(n, d), spec(k, d)),
            ["sums", "counts", "inertia"],
        )

    out["kmeans_step"] = kmeans(65536, 32, 20, 2048)
    out["kmeans_step_small"] = kmeans(4096, 32, 20, 512)
    out["kmeans_step_tiny"] = kmeans(256, 8, 4, 64)

    def kmeans_update(k, d):
        return (
            jax.jit(model.kmeans_update),
            (spec(k, d), spec(k), spec(k, d)),
            ["centers"],
        )

    out["kmeans_update"] = kmeans_update(20, 32)
    out["kmeans_update_tiny"] = kmeans_update(4, 8)

    def phylo(s, a, tile):
        fn = functools.partial(model.phylo_step, tile=tile)
        return (
            jax.jit(fn),
            (spec(s, a), spec(s, a), spec(a, a), spec(a, a), spec(a), spec(s)),
            ["clv", "loglik"],
        )

    out["phylo_step"] = phylo(16384, 4, 4096)
    out["phylo_step_small"] = phylo(1024, 4, 256)

    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (fn, arg_specs, result_names) in variants().items():
        if only and name not in only:
            continue
        lowered = fn.lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        manifest[name] = {
            "file": fname,
            "args": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in arg_specs],
            "results": [
                {"name": rn, **os_} for rn, os_ in zip(result_names, out_shapes)
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest)} variants)")


if __name__ == "__main__":
    main()
