"""L1 Pallas kernel: Felsenstein-pruning CLV update + log-likelihood.

FT-RAxML-NG (§VI-C, Fig 6) is the paper's flagship application: a
phylogenetic maximum-likelihood inference whose per-PE working set is a
slice of the multiple-sequence-alignment (MSA) columns ("sites"). After a
failure, surviving PEs reload their new site slices through ReStore and
resume likelihood computation. The proxy compute step implemented here is
the real inner loop of such codes: a conditional-likelihood-vector (CLV)
update over the sites a PE owns

    clv[s, i] = (sum_j P_l[i, j] clv_l[s, j]) * (sum_j P_r[i, j] clv_r[s, j])

plus the rooted per-site likelihood reduction. Sites are batched site-major
so the per-site 4x4 matvecs become (TILE, A) @ (A, A) matmuls — bandwidth-
bound like production likelihood kernels (DESIGN.md §7).

Lowered with interpret=True (CPU PJRT; see DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4096 sites x 4 states f32 = 64 KiB per CLV block; two children + output
# + site-lik column < 256 KiB VMEM per grid step.
DEFAULT_TILE = 4096


def _phylo_tile_kernel(
    clv_l_ref, clv_r_ref, p_l_ref, p_r_ref, freqs_ref, weights_ref,
    clv_ref, wll_ref,
):
    """One grid step over a (TILE, A) block of sites.

    Block shapes:
      clv_l_ref, clv_r_ref: (TILE, A)   children CLVs
      p_l_ref, p_r_ref:     (A, A)      edge transition matrices
      freqs_ref:            (1, A)      equilibrium base frequencies
      weights_ref:          (TILE,)     site (column-compression) weights
      clv_ref:              (TILE, A)   output parent CLVs
      wll_ref:              (1, 1)      output partial weighted log-likelihood
    """
    left = jnp.dot(clv_l_ref[...], p_l_ref[...].T,
                   preferred_element_type=jnp.float32)
    right = jnp.dot(clv_r_ref[...], p_r_ref[...].T,
                    preferred_element_type=jnp.float32)
    clv = left * right
    clv_ref[...] = clv

    site_lik = jnp.dot(clv, freqs_ref[0, :], preferred_element_type=jnp.float32)
    site_lik = jnp.maximum(site_lik, jnp.finfo(site_lik.dtype).tiny)
    wll_ref[0, 0] = jnp.sum(weights_ref[...] * jnp.log(site_lik))


@functools.partial(jax.jit, static_argnames=("tile",))
def phylo_loglik(clv_l, clv_r, p_l, p_r, freqs, weights, *, tile=DEFAULT_TILE):
    """Fused CLV update + weighted log-likelihood over this PE's sites.

    Args:
      clv_l, clv_r: (S, A) children CLVs; S must be a multiple of `tile`.
      p_l, p_r:     (A, A) transition matrices.
      freqs:        (A,)   equilibrium frequencies.
      weights:      (S,)   per-site weights.

    Returns:
      clv:    (S, A) parent CLVs.
      loglik: ()     weighted log-likelihood.
    """
    s, a = clv_l.shape
    if s % tile != 0:
        raise ValueError(f"site count {s} not divisible by tile {tile}")
    grid = s // tile

    clv, wll = pl.pallas_call(
        _phylo_tile_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, a), lambda i: (i, 0)),
            pl.BlockSpec((tile, a), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((1, a), lambda i: (0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, a), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, a), clv_l.dtype),
            jax.ShapeDtypeStruct((grid, 1), clv_l.dtype),
        ],
        interpret=True,
    )(clv_l, clv_r, p_l, p_r, freqs[None, :], weights)

    return clv, jnp.sum(wll)
