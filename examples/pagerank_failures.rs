//! PageRank scenario: the third application class the paper names (§IV-C).
//!
//! A vertex-partitioned PageRank whose edge lists are protected by
//! ReStore. A failure storm kills ~30 % of the PEs mid-run; the survivors
//! reload the lost edge shards and the final ranks are verified identical
//! to a failure-free run (bit-exact, since edge data recovery is exact and
//! the reduction order is deterministic).
//!
//! Run with: `cargo run --release --example pagerank_failures`

use restore::apps::pagerank::{self, PagerankParams};
use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::simnet::cluster::Cluster;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let p = 16;
    let params = PagerankParams {
        vertices_per_pe: 512,
        edges_per_vertex: 8,
        iterations: 40,
        damping: 0.85,
        failure_fraction: 0.3,
        seed: 23,
    };
    let bs = 64;
    let blocks = params.vertices_per_pe * params.edges_per_vertex * 8 / bs;
    let cfg = RestoreConfig::builder(p, bs, blocks)
        .replicas(4)
        .build()?;

    println!(
        "pagerank: p={p}, {} vertices/PE x {} edges, {} iterations, 30 % failures",
        params.vertices_per_pe, params.edges_per_vertex, params.iterations
    );

    let mut c1 = Cluster::new_execution(p, 4);
    let faulty = pagerank::run(&mut c1, &cfg, &params)?;
    println!(
        "faulty run:  {} failures, survivors {}, delta {:.2e}, sim {} (ReStore {})",
        faulty.failures,
        c1.n_alive(),
        faulty.final_delta,
        fmt_time(faulty.sim_total_s),
        fmt_time(faulty.sim_restore_s)
    );

    let control = PagerankParams { failure_fraction: 0.0, ..params };
    let mut c2 = Cluster::new_execution(p, 4);
    let clean = pagerank::run(&mut c2, &cfg, &control)?;
    println!(
        "control run: 0 failures, delta {:.2e}, sim {}",
        clean.final_delta,
        fmt_time(clean.sim_total_s)
    );

    let max_diff = faulty
        .ranks
        .iter()
        .zip(&clean.ranks)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let mass: f64 = faulty.ranks.iter().sum();
    println!("rank mass {mass:.12} (must be 1); max |Δrank| vs control {max_diff:.2e}");
    if (mass - 1.0).abs() >= 1e-9 {
        return Err("rank mass leaked".into());
    }
    if max_diff >= 1e-12 {
        return Err(format!("ranks diverged after recovery: {max_diff:.2e}").into());
    }
    println!("ranks identical after recovering {} failed PEs — recovery is exact", faulty.failures);
    Ok(())
}
