//! Pseudorandom permutations of permutation-range indices (§IV-B).
//!
//! The paper permutes the IDs of *permutation ranges* (groups of `s_pr`
//! blocks) so that a failed PE's data is scattered over many senders during
//! recovery. The permutation must be computable by every PE without
//! communication and invertible in O(1) — we use a 4-round Feistel network
//! with cycle walking (exactly the construction the paper's own Appendix
//! proposes as "Data Distribution B").

use crate::restore::hashing::seeded_hash;

/// An invertible permutation over `[0, domain)`.
pub trait RangePermutation: Send + Sync {
    fn domain(&self) -> u64;
    /// Forward map (original range index -> permuted slot).
    fn apply(&self, idx: u64) -> u64;
    /// Inverse map (permuted slot -> original range index).
    fn invert(&self, idx: u64) -> u64;
}

/// The identity permutation (permutation ranges disabled).
#[derive(Debug, Clone, Copy)]
pub struct Identity {
    pub domain: u64,
}

impl RangePermutation for Identity {
    fn domain(&self) -> u64 {
        self.domain
    }

    fn apply(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.domain);
        idx
    }

    fn invert(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.domain);
        idx
    }
}

const ROUNDS: usize = 4;

/// Feistel-network permutation over `[0, domain)` via cycle walking.
///
/// A balanced Feistel over `half_bits × 2` bits is a bijection on
/// `[0, 2^(2·half_bits))`; values landing `>= domain` are re-encrypted
/// until they fall inside (cycle walking). Expected walks `< 4` since the
/// power-of-two envelope is at most 4× the domain.
#[derive(Debug, Clone)]
pub struct Feistel {
    domain: u64,
    half_bits: u32,
    keys: [u64; ROUNDS],
}

impl Feistel {
    pub fn new(domain: u64, seed: u64) -> Self {
        assert!(domain > 0);
        // envelope = smallest even-bit power of two >= domain
        let bits = 64 - (domain.max(2) - 1).leading_zeros();
        let half_bits = bits.div_ceil(2);
        let mut keys = [0u64; ROUNDS];
        for (i, k) in keys.iter_mut().enumerate() {
            *k = seeded_hash(seed, i as u64 ^ 0xFE157E1);
        }
        Feistel { domain, half_bits, keys }
    }

    #[inline]
    fn mask(&self) -> u64 {
        (1u64 << self.half_bits) - 1
    }

    #[inline]
    fn round(&self, key: u64, x: u64) -> u64 {
        seeded_hash(key, x) & self.mask()
    }

    #[inline]
    fn encrypt_once(&self, v: u64) -> u64 {
        let mut l = v >> self.half_bits;
        let mut r = v & self.mask();
        for k in self.keys {
            let nl = r;
            let nr = l ^ self.round(k, r);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }

    #[inline]
    fn decrypt_once(&self, v: u64) -> u64 {
        let mut l = v >> self.half_bits;
        let mut r = v & self.mask();
        for k in self.keys.iter().rev() {
            let nr = l;
            let nl = r ^ self.round(*k, l);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }
}

impl RangePermutation for Feistel {
    fn domain(&self) -> u64 {
        self.domain
    }

    fn apply(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.domain);
        let mut v = self.encrypt_once(idx);
        while v >= self.domain {
            v = self.encrypt_once(v);
        }
        v
    }

    fn invert(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.domain);
        let mut v = self.decrypt_once(idx);
        while v >= self.domain {
            v = self.decrypt_once(v);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip() {
        let p = Identity { domain: 100 };
        assert_eq!(p.apply(42), 42);
        assert_eq!(p.invert(42), 42);
    }

    #[test]
    fn feistel_is_a_bijection_small_domains() {
        for domain in [1u64, 2, 3, 7, 64, 100, 257, 4096, 5000] {
            let f = Feistel::new(domain, 0xABCD);
            let mut seen = vec![false; domain as usize];
            for i in 0..domain {
                let y = f.apply(i);
                assert!(y < domain, "domain {domain}: {i} -> {y}");
                assert!(!seen[y as usize], "collision at {y}");
                seen[y as usize] = true;
                assert_eq!(f.invert(y), i, "inverse mismatch");
            }
        }
    }

    #[test]
    fn feistel_differs_by_seed() {
        let a = Feistel::new(1024, 1);
        let b = Feistel::new(1024, 2);
        let same = (0..1024).filter(|&i| a.apply(i) == b.apply(i)).count();
        assert!(same < 32, "seeds produce near-identical permutations");
    }

    #[test]
    fn feistel_scatters_consecutive_indices() {
        // The whole point of §IV-B: consecutive ranges must not stay
        // consecutive. Check mean displacement is large.
        let n = 1u64 << 16;
        let f = Feistel::new(n, 7);
        let mut adjacent = 0;
        for i in 0..n - 1 {
            if f.apply(i + 1).abs_diff(f.apply(i)) == 1 {
                adjacent += 1;
            }
        }
        assert!(adjacent < 8, "{adjacent} adjacent pairs survived");
    }

    #[test]
    fn feistel_large_domain_roundtrip() {
        let f = Feistel::new(1 << 40, 99);
        for i in [0u64, 1, 12345, (1 << 40) - 1, 987654321] {
            assert_eq!(f.invert(f.apply(i)), i);
        }
    }
}
