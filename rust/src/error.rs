//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the ReStore library and its substrates.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid configuration (divisibility constraints, zero sizes, ...).
    #[error("invalid config: {0}")]
    Config(String),

    /// An operation referenced a PE rank outside the world.
    #[error("rank {rank} out of range (world size {world})")]
    RankOutOfRange { rank: usize, world: usize },

    /// The data for a requested block range is irrecoverably lost: all `r`
    /// replicas resided on failed PEs (the paper's IDL event, §IV-D).
    /// Tagged with the dataset whose blocks were lost — a multi-dataset
    /// recovery (`ReStore::load_many`, the fused shrink handshake) needs to
    /// know *which* datatype must fall back to reloading from disk.
    #[error(
        "irrecoverable data loss: all replicas of dataset {dataset} blocks [{start}, {end}) failed"
    )]
    IrrecoverableDataLoss { dataset: crate::restore::registry::DatasetId, start: u64, end: u64 },

    /// An operation referenced a dataset id the registry never created.
    #[error("unknown dataset {dataset} (registry holds {datasets} datasets)")]
    UnknownDataset { dataset: u32, datasets: usize },

    /// submit() called more than once. The paper's library supports
    /// submitting data exactly once per dataset (§V); publishing a *new
    /// version* of already-submitted data goes through the versioned
    /// mutable-dataset path (`Dataset::resubmit` / `resubmit_virtual`)
    /// instead.
    #[error("ReStore::submit may only be called once per instance; use resubmit for new versions")]
    AlreadySubmitted,

    /// load() called before submit().
    #[error("ReStore::load called before submit")]
    NotSubmitted,

    /// A collective was attempted on a dead PE.
    #[error("PE {0} is dead")]
    DeadPe(usize),

    /// A `ReStore` operation ran against a cluster whose communicator has
    /// been reconfigured — `ulfm::shrink`, `ulfm::substitute`, and
    /// `ulfm::grow` ALL bump the epoch — without the store adopting the
    /// new world first. Call `ReStore::rebalance_or_acknowledge` (or its
    /// `_all` registry form) with the map the primitive returned, or let a
    /// `restore::policy::RecoveryPolicy` drive the whole agree →
    /// {shrink | substitute | grow} → reshape handshake for you.
    #[error(
        "stale storage epoch: store layout observed at epoch {store_epoch}, expected the \
         cluster's current epoch {cluster_epoch}; call ReStore::rebalance_or_acknowledge (or \
         run a restore::policy::RecoveryPolicy) after ulfm::shrink/substitute/grow"
    )]
    StaleEpoch { store_epoch: u64, cluster_epoch: u64 },

    /// A `RankMap` no longer (or never) described the cluster's current
    /// communicator — e.g. it came from an earlier shrink, substitute, or
    /// grow and further PEs failed (or another reconfiguration landed)
    /// since. The reshape layer (`ReStore::rebalance` /
    /// `rebalance_or_acknowledge`) and every `restore::policy` policy
    /// validate the map up front so a stale map can never steer them into
    /// the wrong branch; re-run the `ulfm` primitive after the latest
    /// failures to obtain a current map.
    #[error("stale rank map: {0}; re-run ulfm shrink/substitute/grow after the latest failures")]
    StaleRankMap(String),

    /// A versioned resubmit was torn down mid-flight: a failure or a
    /// communicator reconfiguration (epoch bump) landed between staging
    /// and commit, so the staged version was discarded whole. Loads keep
    /// serving the last *committed* version (`version`) byte-exactly —
    /// never a torn mix of old and new blocks. Re-drive recovery (the
    /// usual rebalance/acknowledge handshake), then retry the resubmit.
    #[error(
        "resubmit of dataset {dataset} aborted before commit; the staged version was discarded \
         and loads keep serving committed version {version}"
    )]
    ResubmitAborted { dataset: crate::restore::registry::DatasetId, version: u64 },

    /// A stored block's bytes no longer match the checksum latched at
    /// submit time — silent corruption (bit rot, a torn write) on the
    /// named holder. The integrity layer never serves such bytes: `load`
    /// assembly, repair ingest, and rebalance ingest all verify before
    /// copying. `Dataset::scrub` quarantines the holder's copy in the
    /// `HolderIndex` and repairs it from a surviving verified replica.
    #[error(
        "corrupt block {block} of dataset {dataset} on holder PE {holder}: stored bytes fail \
         checksum verification; the copy is quarantined from serving — run Dataset::scrub to \
         repair it from a surviving replica"
    )]
    CorruptBlock { dataset: crate::restore::registry::DatasetId, block: u64, holder: usize },

    /// A KV operation referenced a key at or beyond the dataset's key
    /// space (keys are block ids: `[0, n_blocks)`).
    #[error("kv: key {key} out of range for dataset {dataset} ({keys} keys)")]
    KeyOutOfRange { dataset: crate::restore::registry::DatasetId, key: u64, keys: u64 },

    /// PJRT / XLA runtime error (only constructed with the `pjrt` feature;
    /// the variant itself stays so error handling is feature-independent).
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Artifact registry problems (missing manifest, unknown variant...).
    #[error("artifact: {0}")]
    Artifact(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Config/manifest text could not be parsed.
    #[error("parse: {0}")]
    Parse(String),
}

impl Error {
    /// Re-tag an [`Error::IrrecoverableDataLoss`] with the dataset it
    /// belongs to (identity on every other variant). Used by the layers
    /// that plan in dataset-agnostic terms (e.g.
    /// `restore::rebalance::plan_rebalance`) whose callers know the id.
    pub(crate) fn tag_dataset(self, id: crate::restore::registry::DatasetId) -> Error {
        match self {
            Error::IrrecoverableDataLoss { start, end, .. } => {
                Error::IrrecoverableDataLoss { dataset: id, start, end }
            }
            Error::CorruptBlock { block, holder, .. } => {
                Error::CorruptBlock { dataset: id, block, holder }
            }
            other => other,
        }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Parse(e.to_string())
    }
}

impl From<crate::util::toml::TomlError> for Error {
    fn from(e: crate::util::toml::TomlError) -> Self {
        Error::Parse(e.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
