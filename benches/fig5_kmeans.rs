//! Fig 5 — fault-tolerant k-means running time breakdown (§VI-C).
//!
//! Paper setup: 65 536 points × 32 dims per PE (16 MiB), 20 shared random
//! starting centers, 500 Lloyd iterations, expected 1 % of PEs failing via
//! discrete exponential decay; shrinking recovery through ReStore.
//!
//! Two parts:
//! 1. Execution mode (p = 16, scaled-down points): real PJRT kernels, real
//!    recovery — also calibrates the per-iteration compute time.
//! 2. Cost-model mode at the paper's PE counts (48 … 24576): identical
//!    control flow and communication schedules.
//!
//! Paper anchors: ReStore accounts for only ~1.6 % (median) of the overall
//! running time; the remaining overhead growth at scale comes from the MPI
//! operations that restore a functioning communicator.

use restore::apps::kmeans::{self, KmeansParams};
use restore::config::RestoreConfig;
use restore::metrics::{fmt_time, Table};
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;

const BLOCK: usize = 64;

fn main() {
    // --- Part 1: execution mode + compute calibration ----------------------
    println!("=== Fig 5 part 1: execution mode (real PJRT kernels), p=16 ===\n");
    let mut params = KmeansParams {
        points_per_pe: 4096,
        dims: 32,
        k: 20,
        iterations: 30,
        failure_fraction: 0.15,
        seed: 5,
        step_variant: "kmeans_step_small".into(),
        update_variant: "kmeans_update".into(),
    };
    let bytes = params.points_per_pe * params.dims * 4;
    let cfg = RestoreConfig::builder(16, BLOCK, bytes / BLOCK)
        .replicas(4)
        .perm_range_bytes(Some(64 * 1024))
        .build()
        .unwrap();
    let mut engine = Engine::load_default().expect("run `make artifacts` first");
    let mut cluster = Cluster::new_execution(16, 4);
    let rep = kmeans::run_execution(&mut cluster, &mut engine, &cfg, &params).unwrap();
    println!(
        "p=16: {} failures, overall {}, loop {}, ReStore {} ({:.2} %), MPI {}",
        rep.failures,
        fmt_time(rep.sim_total_s),
        fmt_time(rep.sim_kmeans_loop_s),
        fmt_time(rep.sim_restore_s),
        100.0 * rep.sim_restore_s / rep.sim_total_s,
        fmt_time(rep.sim_mpi_recovery_s)
    );
    // calibrate: measured per-exec wall time, scaled to the paper's 65536
    // points (16x the small artifact's 4096)
    let per_exec = rep.wall_compute_s / engine.exec_calls as f64;
    let compute_s_per_iter = per_exec * (65536.0 / params.points_per_pe as f64);
    println!(
        "calibration: {} per 4096-point exec -> {} per 65536-point paper iteration\n",
        fmt_time(per_exec),
        fmt_time(compute_s_per_iter)
    );

    // --- Part 2: cost-model mode at the paper's scale -----------------------
    println!("=== Fig 5 part 2: cost-model mode, paper configuration ===");
    println!("(500 iterations, 16 MiB/PE, 1 % failures, r=4, 256 KiB perm ranges)\n");
    params = KmeansParams::paper();
    let mut table = Table::new(vec![
        "p",
        "failures",
        "overall",
        "k-means loop",
        "ReStore",
        "ReStore %",
        "MPI recovery",
    ]);
    let mut restore_pcts: Vec<f64> = Vec::new();
    let mut scaled_pcts: Vec<f64> = Vec::new();
    for &p in &[48usize, 192, 768, 3072, 12288, 24576] {
        let cfg = RestoreConfig::paper_default(p).unwrap();
        let mut cluster = Cluster::new_execution(p, 48.min(p));
        let mut run_params = params.clone();
        run_params.seed = 42 + p as u64;
        let rep =
            kmeans::run_cost_model(&mut cluster, &cfg, &run_params, compute_s_per_iter).unwrap();
        let pct = 100.0 * rep.sim_restore_s / rep.sim_total_s;
        restore_pcts.push(pct);
        // sensitivity: on SuperMUC-NG 48 ranks share a node's memory
        // bandwidth; per-rank compute is ~4x slower than our single
        // dedicated core -> the paper-equivalent share divides by the
        // correspondingly larger loop time
        scaled_pcts.push(
            100.0 * rep.sim_restore_s / (rep.sim_total_s + 3.0 * rep.sim_kmeans_loop_s),
        );
        table.row(vec![
            p.to_string(),
            rep.failures.to_string(),
            fmt_time(rep.sim_total_s),
            fmt_time(rep.sim_kmeans_loop_s),
            fmt_time(rep.sim_restore_s),
            format!("{pct:.2}%"),
            fmt_time(rep.sim_mpi_recovery_s),
        ]);
    }
    println!("{}", table.render());
    restore_pcts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    scaled_pcts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = restore_pcts[restore_pcts.len() / 2];
    let scaled = scaled_pcts[scaled_pcts.len() / 2];
    println!(
        "paper anchor: ReStore is ~1.6 % (median) of overall time at up to 24576 PEs\n\
         measured median: {median:.2} % (optimistic single-core compute calibration);\n\
         {scaled:.2} % with node-shared-bandwidth compute (EXPERIMENTS.md §Fig5) {}",
        if scaled < 5.0 { "[OK: minor overhead]" } else { "[MISMATCH]" }
    );
    println!(
        "paper anchor: overhead at scale driven by MPI communicator recovery, not ReStore\n\
         (compare the MPI column's growth vs the ReStore column) [OK by inspection]"
    );
}
