//! Minimal TOML parser — in-tree replacement for the `toml` crate,
//! sufficient for the experiment files: `[table]` / `[a.b]` headers and
//! `key = value` lines with string / integer / float / bool values, plus
//! `#` comments.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML document: dotted-path -> value (`[restore]` + `seed = 1`
/// becomes `"restore.seed"`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: String| TomlError { line: lineno + 1, msg };
            if let Some(table) = line.strip_prefix('[') {
                let table = table
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header".into()))?
                    .trim();
                if table.is_empty() {
                    return Err(err("empty table name".into()));
                }
                prefix = format!("{table}.");
            } else {
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| err(format!("expected key = value, got '{line}'")))?;
                let key = format!("{prefix}{}", k.trim());
                let value = parse_value(v.trim())
                    .ok_or_else(|| err(format!("bad value '{}'", v.trim())))?;
                doc.values.insert(key, value);
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    pub fn get_usize(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(TomlValue::as_usize)
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(TomlValue::as_f64)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(TomlValue::as_str)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(TomlValue::as_bool)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if let Some(stripped) = v.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        return Some(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

/// Serialize helper used by `ExperimentFile::to_toml`.
pub fn escape_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_experiment_like_file() {
        let doc = TomlDoc::parse(
            r#"
            # experiment
            world = 48
            pes_per_node = 48

            [restore]
            block_size = 64        # bytes
            replicas = 4
            perm_range_bytes = 262144
            permutation = true
            seed = 0x_invalid_is_not_here = no
            "#,
        );
        // the bogus line should error
        assert!(doc.is_err());

        let doc = TomlDoc::parse(
            r#"
            world = 48
            [restore]
            block_size = 64
            replicas = 4
            failure_fraction = 0.01
            label = "paper default"
            permutation = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_usize("world"), Some(48));
        assert_eq!(doc.get_usize("restore.block_size"), Some(64));
        assert_eq!(doc.get_f64("restore.failure_fraction"), Some(0.01));
        assert_eq!(doc.get_str("restore.label"), Some("paper default"));
        assert_eq!(doc.get_bool("restore.permutation"), Some(true));
    }

    #[test]
    fn comments_and_underscores() {
        let doc = TomlDoc::parse("a = 1_000_000 # one million\nb = \"x # y\"").unwrap();
        assert_eq!(doc.get_usize("a"), Some(1_000_000));
        assert_eq!(doc.get_str("b"), Some("x # y"));
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("i = 3\nf = 3.5\nneg = -2").unwrap();
        assert_eq!(doc.get("i"), Some(&TomlValue::Int(3)));
        assert_eq!(doc.get("f"), Some(&TomlValue::Float(3.5)));
        assert_eq!(doc.get("neg"), Some(&TomlValue::Int(-2)));
        assert_eq!(doc.get_f64("i"), Some(3.0)); // int coerces to f64
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken line").unwrap_err();
        assert_eq!(err.line, 2);
        let err = TomlDoc::parse("[unclosed").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn escape_roundtrip() {
        let doc = TomlDoc::parse(&format!("s = {}", escape_str("a\"b\\c"))).unwrap();
        assert_eq!(doc.get_str("s"), Some("a\"b\\c"));
    }
}
