//! Incremental integrity scrubbing.
//!
//! The checksums latched at submit time (`restore/store.rs`) catch silent
//! corruption *when a rotten copy is touched* — on load assembly, on a
//! repair source, on a rebalance keep/source. A replica that nobody reads
//! can rot unnoticed for arbitrarily long, though, and the longer it sits
//! the higher the chance a *second* copy of the same slice rots too,
//! turning a repairable single-copy event into §IV-D data loss. The fix is
//! the classic storage-system answer: a background **scrub** that walks the
//! resident replicas on a budget, cross-checks every block against its
//! checksum, quarantines copies that fail, and re-creates them from a
//! surviving replica with the existing §IV-E repair machinery.
//!
//! [`Dataset::scrub`] is that walk. It is *incremental*: a persistent
//! per-dataset cursor ([`Dataset::scrub_slot`]) remembers the next permuted
//! slot to verify, each call verifies whole slots (every alive copy of a
//! slot is checked together, so a corrupt copy is quarantined while its
//! siblings are provably good) until the block budget is spent or the
//! cursor wraps, and the clean path allocates nothing — the scan reads the
//! reverse holder index and the per-slice checksum tables in place, so an
//! application can afford to interleave small scrub steps with its real
//! work.
//!
//! Quarantine removes the corrupt copy from BOTH the [`HolderIndex`]
//! (routing: the load path and repair planning stop seeing it) and the
//! [`PeStore`] (bytes: the rotten slice is dropped). The §IV-E repair
//! round that follows re-creates the copy — on the *same* PE, since the
//! deterministic §IV-A home is alive and merely lost its replica. Only
//! when corruption has eaten ALL `r` copies of a slot is the slot
//! irrecoverable; the report counts those, and a subsequent targeted load
//! surfaces [`Error::IrrecoverableDataLoss`] exactly as §IV-D predicts
//! (see `restore/idl.rs` for the corruption-extended IDL model).
//!
//! [`HolderIndex`]: crate::restore::store::HolderIndex
//! [`PeStore`]: crate::restore::store::PeStore
//! [`Error::IrrecoverableDataLoss`]: crate::error::Error::IrrecoverableDataLoss

use crate::error::Result;
use crate::restore::registry::Dataset;
use crate::restore::repair::{charge_repair_plans, RepairScheme};
use crate::restore::ReStore;
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

/// Probing-sequence construction the scrub's repair round uses — the same
/// Appendix Distribution A double hashing the recovery policies repair
/// with, so a scrub-triggered re-creation lands on exactly the home a
/// failure-triggered repair would pick (idempotence across the two paths).
pub const SCRUB_REPAIR_SCHEME: RepairScheme = RepairScheme::DoubleHashing;

/// What one [`Dataset::scrub`] call found and did.
#[derive(Debug, Clone, Default)]
pub struct ScrubReport {
    /// Blocks whose checksums were cross-checked this call, summed over
    /// every alive copy scanned (a slot with `r` alive holders charges
    /// `r · slice_len` against the budget).
    pub scanned_blocks: u64,
    /// Blocks that failed verification.
    pub corrupt_blocks: u64,
    /// Copies (slot, holder) quarantined: dropped from the holder index
    /// and the holder's store, pending repair.
    pub quarantined: usize,
    /// Replica units re-created by the §IV-E repair round this call
    /// triggered (covers the quarantined copies and any other currently
    /// missing replicas — repair is idempotent and heals everything due).
    pub repaired: usize,
    /// Slots where corruption ate EVERY remaining alive copy: nothing to
    /// repair from; a targeted load of those blocks reports
    /// [`Error::IrrecoverableDataLoss`](crate::error::Error::IrrecoverableDataLoss).
    pub irrecoverable: usize,
    /// Did the cursor complete a full circle over the slot space?
    pub wrapped: bool,
    /// Network cost of the repair round (zero when nothing was corrupt —
    /// the scan itself is local and free under the cost model).
    pub cost: PhaseCost,
}

impl Dataset {
    /// Verify up to `budget_blocks` resident blocks (counted per copy)
    /// against their checksums, starting at the persistent cursor;
    /// quarantine and repair what fails. At least one slot is always
    /// processed, so any positive budget makes progress and repeated calls
    /// eventually wrap the whole dataset (`wrapped` in the report).
    ///
    /// Cost-model datasets (`submit_virtual`) have no bytes to verify:
    /// scrub returns a zero report and leaves the cursor alone.
    ///
    /// Like every routing operation, scrub refuses to run over a stale
    /// communicator ([`Error::StaleEpoch`](crate::error::Error::StaleEpoch)):
    /// rebalance or acknowledge first, which also re-clamps the cursor
    /// into the (possibly shrunk) new slot space.
    ///
    /// The cursor also survives the mutable-dataset write path: an
    /// in-place [`Dataset::resubmit`](crate::restore::Dataset::resubmit)
    /// keeps it (same slot space; commit re-latches the written blocks'
    /// checksums, so the ongoing wrap keeps verifying clean), while a
    /// shape-changing
    /// [`Dataset::resubmit_reshaped`](crate::restore::Dataset::resubmit_reshaped)
    /// resets it to 0 — and the entry clamp below backstops any path that
    /// shrinks the slot space under a mid-wrap cursor.
    pub fn scrub(&mut self, cluster: &mut Cluster, budget_blocks: u64) -> Result<ScrubReport> {
        self.ensure_submitted()?;
        self.ensure_current_epoch(cluster)?;
        if !self.is_execution_mode() {
            return Ok(ScrubReport::default());
        }

        let slots = self.dist.world();
        if self.scrub_slot >= slots {
            // a rebalance shrank the slot space under the cursor
            self.scrub_slot = 0;
        }
        let mut visited = 0usize;
        let mut scanned = 0u64;
        let mut found = 0u64;
        // (slot, holder) pairs to quarantine, pushed in slot-grouped walk
        // order; lazily allocated so the clean path allocates nothing.
        let mut corrupt: Vec<(usize, usize)> = Vec::new();
        loop {
            let slot = self.scrub_slot;
            let range = self.dist.slice_range(slot);
            for &pe in self.holder_index.holders_of(slot) {
                let pe = pe as usize;
                if !cluster.is_alive(pe) {
                    continue; // dead copies are reclaim's business, not ours
                }
                let bad = self.stores[pe].corrupt_blocks(range.start, range.len());
                scanned += range.len();
                if bad > 0 {
                    found += bad;
                    corrupt.push((slot, pe));
                }
            }
            self.scrub_slot = (slot + 1) % slots;
            visited += 1;
            if visited >= slots || scanned >= budget_blocks {
                break;
            }
        }
        let wrapped = visited >= slots;

        // Quarantine: drop each corrupt copy from routing (holder index)
        // AND storage (the slice itself) — removing only one would either
        // keep serving rotten bytes or make repair insert an overlapping
        // duplicate over them.
        let mut quarantined = 0usize;
        for &(slot, pe) in &corrupt {
            let range = self.dist.slice_range(slot);
            let in_index = self.holder_index.remove(slot, pe);
            let in_store = self.stores[pe].remove(range.start, range.len());
            debug_assert!(in_index && in_store, "quarantined copy missing from index or store");
            quarantined += 1;
        }

        // Slots with no alive copy left are beyond repair. `corrupt` is
        // slot-grouped (the walk finishes a slot before moving on), so
        // adjacent dedup counts each slot once.
        let mut irrecoverable = 0usize;
        let mut prev_slot = usize::MAX;
        for &(slot, _) in &corrupt {
            if slot == prev_slot {
                continue;
            }
            prev_slot = slot;
            let survivor = self
                .holder_index
                .holders_of(slot)
                .iter()
                .any(|&pe| cluster.is_alive(pe as usize));
            if !survivor {
                irrecoverable += 1;
            }
        }

        let mut repaired = 0usize;
        let mut cost = PhaseCost::default();
        if quarantined > 0 {
            let plan = self.plan_repair(cluster, SCRUB_REPAIR_SCHEME)?;
            let bs = self.cfg.block_size as u64;
            let phase = charge_repair_plans(cluster, &[(&plan, bs)])?;
            let report = self.apply_repair(plan, phase)?;
            repaired = report.transfers;
            cost = report.cost;
        }

        Ok(ScrubReport {
            scanned_blocks: scanned,
            corrupt_blocks: found,
            quarantined,
            repaired,
            irrecoverable,
            wrapped,
            cost,
        })
    }

    /// Flip one stored bit on PE `pe` — the silent-corruption injection
    /// surface the fault models drive (`simnet/failure.rs`). `byte`
    /// indexes the concatenation of that PE's real payloads
    /// ([`PeStore::corrupt_bit_at`](crate::restore::store::PeStore::corrupt_bit_at));
    /// returns the *original* block id whose content changed, or None when
    /// the offset is past the resident bytes (the strike missed).
    pub fn corrupt_bit(&mut self, pe: usize, byte: u64, bit: u8) -> Option<u64> {
        let y = self.stores[pe].corrupt_bit_at(byte, bit)?;
        Some(self.dist.unpermute_block(y))
    }
}

impl ReStore {
    /// [`Dataset::scrub`] on dataset 0 (the single-dataset facade).
    pub fn scrub(&mut self, cluster: &mut Cluster, budget_blocks: u64) -> Result<ScrubReport> {
        self.datasets[0].scrub(cluster, budget_blocks)
    }

    /// [`Dataset::corrupt_bit`] on dataset 0 (the single-dataset facade).
    pub fn corrupt_bit(&mut self, pe: usize, byte: u64, bit: u8) -> Option<u64> {
        self.datasets[0].corrupt_bit(pe, byte, bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::error::Error;
    use crate::restore::block::{BlockRange, RangeSet};
    use crate::restore::store::HolderIndex;
    use crate::restore::LoadRequest;

    const P: usize = 16;
    const BS: usize = 8; // bytes per block
    const BPP: usize = 64; // blocks per PE
    const R: usize = 4;

    fn build() -> (Cluster, ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(P, BS, BPP).replicas(R).build().unwrap();
        let mut cluster = Cluster::new_execution(P, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards: Vec<Vec<u8>> = (0..P)
            .map(|pe| (0..BPP * BS).map(|i| (pe * 31 + i * 7) as u8).collect())
            .collect();
        rs.submit(&mut cluster, &shards).unwrap();
        (cluster, rs, shards)
    }

    /// Byte-exact golden reload of the whole dataset from one survivor.
    fn assert_full_reload(rs: &mut ReStore, cluster: &mut Cluster, shards: &[Vec<u8>]) {
        let pe = cluster.survivors()[0];
        let n = (shards.len() * BPP) as u64;
        let reqs =
            vec![LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(0, n)]) }];
        let out = rs.load(cluster, &reqs).unwrap();
        let mut want = Vec::with_capacity(shards.len() * BPP * BS);
        for x in 0..n as usize {
            let (pe, off) = (x / BPP, (x % BPP) * BS);
            want.extend_from_slice(&shards[pe][off..off + BS]);
        }
        assert_eq!(out.shards[0].bytes.as_deref().unwrap(), &want[..]);
    }

    /// Cluster ranks of all `R` copies of original block `x`.
    fn copy_holders(rs: &ReStore, x: u64) -> (u64, Vec<usize>) {
        let ds = &rs.datasets()[0];
        let y = ds.distribution().permute_block(x);
        let holders =
            (0..R).map(|k| ds.cluster_rank(ds.distribution().holder(y, k))).collect();
        (y, holders)
    }

    #[test]
    fn clean_scrub_wraps_counts_every_copy_and_is_free() {
        let (mut cluster, mut rs, _) = build();
        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert!(report.wrapped);
        // every slot has R alive copies: R · n blocks cross-checked
        assert_eq!(report.scanned_blocks, (R * P * BPP) as u64);
        assert_eq!(report.corrupt_blocks, 0);
        assert_eq!(report.quarantined, 0);
        assert_eq!(report.repaired, 0);
        assert_eq!(report.irrecoverable, 0);
        assert_eq!(report.cost, PhaseCost::default(), "clean scan charges nothing");
        assert_eq!(rs.datasets()[0].scrub_slot, 0, "full circle parks the cursor home");
    }

    #[test]
    fn scrub_budget_advances_the_cursor_incrementally() {
        let (mut cluster, mut rs, _) = build();
        // one slot costs R · BPP scanned blocks; budget exactly one slot
        let per_slot = (R * BPP) as u64;
        for step in 1..=P {
            let report = rs.scrub(&mut cluster, per_slot).unwrap();
            assert_eq!(report.scanned_blocks, per_slot, "step {step}");
            assert!(!report.wrapped, "step {step}: one slot is not a full circle");
            assert_eq!(rs.datasets()[0].scrub_slot, step % P, "step {step}");
        }
        // budget 0 still makes progress (exactly one slot)
        let report = rs.scrub(&mut cluster, 0).unwrap();
        assert_eq!(report.scanned_blocks, per_slot);
        assert_eq!(rs.datasets()[0].scrub_slot, 1);
    }

    #[test]
    fn scrub_quarantines_and_repairs_a_corrupt_copy() {
        let (mut cluster, mut rs, shards) = build();
        let x = 100u64;
        let (y, holders) = copy_holders(&rs, x);
        let victim = holders[0];
        assert!(rs.datasets[0].stores[victim].corrupt_block_bit(y, 3));

        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert!(report.wrapped);
        assert_eq!(report.corrupt_blocks, 1);
        assert_eq!(report.quarantined, 1);
        assert_eq!(report.repaired, 1, "exactly the quarantined copy is re-created");
        assert_eq!(report.irrecoverable, 0);

        // the copy is back on the same PE (its deterministic home is
        // alive), byte-identical to its siblings, and the index matches a
        // from-scratch rescan
        let ds = &rs.datasets()[0];
        assert!(ds.stores()[victim].holds(y, 1));
        assert_eq!(ds.stores()[victim].verify(y, 1), None);
        assert_eq!(
            *rs.holder_index(),
            HolderIndex::rebuild(rs.stores(), rs.distribution()),
            "holder index drifted"
        );
        assert_full_reload(&mut rs, &mut cluster, &shards);

        // a second pass finds nothing left to do
        let again = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert_eq!(again.corrupt_blocks, 0);
        assert_eq!(again.quarantined, 0);
        assert_eq!(again.repaired, 0);
    }

    #[test]
    fn all_copies_corrupt_is_irrecoverable_and_load_says_so() {
        let (mut cluster, mut rs, _) = build();
        let x = 42u64;
        let (y, holders) = copy_holders(&rs, x);
        for &pe in &holders {
            assert!(rs.datasets[0].stores[pe].corrupt_block_bit(y, 2));
        }

        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert_eq!(report.corrupt_blocks, R as u64);
        assert_eq!(report.quarantined, R);
        assert_eq!(report.irrecoverable, 1, "no surviving copy to repair from");
        assert_eq!(report.repaired, 0);

        // targeted load of the lost block: IDL naming the original range
        let reqs = vec![LoadRequest {
            pe: 0,
            ranges: RangeSet::new(vec![BlockRange::new(x, x + 1)]),
        }];
        match rs.load(&mut cluster, &reqs) {
            Err(Error::IrrecoverableDataLoss { start, end, .. }) => {
                assert_eq!((start, end), (x, x + 1));
            }
            other => panic!("expected IrrecoverableDataLoss, got {other:?}"),
        }
        // untouched blocks still load fine around the crater
        let reqs = vec![LoadRequest {
            pe: 0,
            ranges: RangeSet::new(vec![BlockRange::new(x + 1, x + 9)]),
        }];
        assert!(rs.load(&mut cluster, &reqs).is_ok());
    }

    #[test]
    fn corrupt_bit_names_the_original_block_and_scrub_finds_it() {
        let (mut cluster, mut rs, _) = build();
        let hit = rs.corrupt_bit(7, 40, 1).expect("offset 40 is resident");
        assert!(hit < (P * BPP) as u64, "original block id");
        // past the R · BPP · BS resident bytes: the strike misses
        assert_eq!(rs.corrupt_bit(7, (R * BPP * BS) as u64, 1), None);
        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert_eq!(report.corrupt_blocks, 1);
        assert_eq!(report.quarantined, 1);
        assert_eq!(report.repaired, 1);
    }

    /// Regression: a resubmit that shrinks the dataset below the current
    /// slot count must not leave a mid-wrap scrub cursor pointing past the
    /// end of the new slot space (an out-of-range `slice_range` walk).
    #[test]
    fn scrub_cursor_survives_shrinking_resubmit_mid_wrap() {
        use crate::restore::Overlap;
        let (mut cluster, mut rs, _) = build();
        // park the cursor deep into the wrap: 12 of 16 slots visited
        let per_slot = (R * BPP) as u64;
        for _ in 0..12 {
            rs.scrub(&mut cluster, per_slot).unwrap();
        }
        assert_eq!(rs.datasets()[0].scrub_slot, 12);

        // in-place resubmit: slot space unchanged, cursor stays put and the
        // rest of the wrap verifies the re-latched checksums clean
        let new_shards: Vec<Vec<u8>> =
            (0..P).map(|pe| (0..BPP * BS).map(|i| (pe * 13 + i) as u8).collect()).collect();
        rs.resubmit(
            &mut cluster,
            &new_shards,
            crate::restore::ResubmitMode::Full,
            Overlap::Blocking,
        )
        .unwrap();
        assert_eq!(rs.datasets()[0].scrub_slot, 12, "in-place resubmit keeps the cursor");
        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert_eq!(report.corrupt_blocks, 0, "new version scrubs clean");

        // park mid-wrap again, then shrink to 8 blocks (8 slots < cursor):
        // the shape-changing resubmit resets the cursor and the next scrub
        // walks the new, smaller slot space without panicking
        for _ in 0..12 {
            rs.scrub(&mut cluster, per_slot).unwrap();
        }
        assert_eq!(rs.datasets()[0].scrub_slot, 12);
        let small: Vec<u8> = (0..8 * BS).map(|i| i as u8).collect();
        rs.datasets[0].resubmit_reshaped(&mut cluster, &small, Overlap::Blocking).unwrap();
        assert_eq!(rs.datasets()[0].scrub_slot, 0, "shape change resets the cursor");
        assert_eq!(rs.distribution().world(), 8);
        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert!(report.wrapped);
        assert_eq!(report.corrupt_blocks, 0);
        assert_eq!(report.scanned_blocks, (R * 8) as u64, "R copies of 8 blocks");
    }

    #[test]
    fn cost_model_scrub_is_a_zero_report() {
        let cfg = RestoreConfig::builder(P, BS, BPP).replicas(R).build().unwrap();
        let mut cluster = Cluster::new_execution(P, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        let report = rs.scrub(&mut cluster, u64::MAX).unwrap();
        assert_eq!(report.scanned_blocks, 0);
        assert!(!report.wrapped);
        assert_eq!(rs.datasets()[0].scrub_slot, 0, "cursor untouched");
    }
}
