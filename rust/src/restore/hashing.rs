//! Hashing utilities shared by the permutation and repair distributions.
//!
//! The paper's Appendix builds its replica-repair probing sequences from
//! "fast-to-compute hash functions that avoid collisions" plus coprimality
//! checks against the prime factors of `p` (Distribution A) and a Feistel
//! network with cycle walking (Distribution B). This module provides those
//! primitives.

/// SplitMix64 — a fast, well-mixed 64-bit hash (the paper's `f` / `h_s`).
/// The seed parametrizes the family, `h_s(x) = splitmix64(x ^ mix(s))`.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Seeded hash family.
#[inline]
pub fn seeded_hash(seed: u64, x: u64) -> u64 {
    splitmix64(x ^ splitmix64(seed))
}

/// Prime factorization by trial division (run once per program start on the
/// node count `p` — the paper's Appendix; Erdős–Kac: ~3 distinct factors
/// for p < 10^9, so this is trivially fast for any realistic node count).
pub fn prime_factors(mut n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 2u64;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// Is `x` coprime to the number whose distinct prime factors are `factors`?
/// (The Appendix's "< m·1.65 divisions" check.)
#[inline]
pub fn coprime_to_factors(x: u64, factors: &[u64]) -> bool {
    if x == 0 {
        return false;
    }
    factors.iter().all(|&f| x % f != 0)
}

/// GCD (for tests / the slow path).
pub fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// 64-bit content checksum over `bytes` in the xxhash shape — an 8-byte
/// lane absorbed per round through the [`splitmix64`] finalizer — built
/// entirely from the in-tree primitives (no new deps). The seed
/// parametrizes the family; the integrity layer mixes the permuted block
/// id into it so a block's checksum also binds its *position* (a
/// misdirected-but-intact write fails verification too). Length is
/// absorbed up front, so `[0]` and `[0, 0]` differ; the tail (< 8 bytes)
/// is absorbed zero-padded together with its length.
#[inline]
pub fn block_checksum(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = splitmix64(seed ^ (bytes.len() as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let lane = u64::from_le_bytes(c.try_into().expect("chunks_exact yields 8 bytes"));
        h = splitmix64(h ^ lane);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h = splitmix64(h ^ u64::from_le_bytes(tail) ^ ((rem.len() as u64) << 56));
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_spreads() {
        assert_eq!(splitmix64(1), splitmix64(1));
        assert_ne!(splitmix64(1), splitmix64(2));
        // Low bits must vary too (used mod p).
        let lows: std::collections::HashSet<u64> =
            (0..1000u64).map(|x| splitmix64(x) % 64).collect();
        assert!(lows.len() > 32);
    }

    #[test]
    fn factors_of_500() {
        // Paper's Appendix example: p = 500 has prime factors 2 and 5.
        assert_eq!(prime_factors(500), vec![2, 5]);
        assert_eq!(prime_factors(1), Vec::<u64>::new());
        assert_eq!(prime_factors(97), vec![97]);
        assert_eq!(prime_factors(24576), vec![2, 3]);
    }

    #[test]
    fn coprimality_matches_gcd() {
        let p = 500u64;
        let fs = prime_factors(p);
        for x in 1..200u64 {
            assert_eq!(coprime_to_factors(x, &fs), gcd(x, p) == 1, "x={x}");
        }
        assert!(!coprime_to_factors(0, &fs));
    }

    #[test]
    fn block_checksum_detects_every_single_bit_flip() {
        let data: Vec<u8> = (0..24u8).collect();
        let base = block_checksum(7, &data);
        assert_eq!(base, block_checksum(7, &data), "deterministic");
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(base, block_checksum(7, &flipped), "byte {byte} bit {bit}");
            }
        }
    }

    #[test]
    fn block_checksum_binds_seed_length_and_tail() {
        assert_ne!(block_checksum(1, &[0u8; 8]), block_checksum(2, &[0u8; 8]));
        assert_ne!(block_checksum(1, &[0u8; 8]), block_checksum(1, &[0u8; 16]));
        // tail bytes (non-multiple-of-8 lengths) are absorbed, not dropped
        assert_ne!(block_checksum(1, &[0u8; 9]), block_checksum(1, &[0u8; 10]));
        assert_ne!(block_checksum(1, &[1, 2, 3]), block_checksum(1, &[1, 2, 4]));
        assert_eq!(block_checksum(1, &[]), block_checksum(1, &[]));
    }

    #[test]
    fn appendix_example_coprimality() {
        // h_s(x)=3 coprime to 500; h_s(y)=20 not; h_s'(y)=7 coprime.
        let fs = prime_factors(500);
        assert!(coprime_to_factors(3, &fs));
        assert!(!coprime_to_factors(20, &fs));
        assert!(coprime_to_factors(7, &fs));
    }
}
