"""Pallas k-means kernel vs pure-jnp oracle — the CORE correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.kmeans import kmeans_assign
from compile.kernels.ref import kmeans_assign_ref, kmeans_update_ref
from compile import model


def random_case(rng, n, d, k):
    points = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    centers = jnp.asarray(rng.standard_normal((k, d)), dtype=jnp.float32)
    return points, centers


def check(points, centers, tile):
    sums, counts, inertia = kmeans_assign(points, centers, tile=tile)
    rsums, rcounts, rinertia = kmeans_assign_ref(points, centers)
    np.testing.assert_allclose(sums, rsums, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(counts, rcounts, rtol=0, atol=0)
    np.testing.assert_allclose(inertia, rinertia, rtol=1e-4, atol=1e-2)


def test_paper_shape_one_tile():
    rng = np.random.default_rng(0)
    check(*random_case(rng, 2048, 32, 20), tile=2048)


def test_paper_shape_multi_tile():
    rng = np.random.default_rng(1)
    check(*random_case(rng, 8192, 32, 20), tile=2048)


def test_tiny_shape():
    rng = np.random.default_rng(2)
    check(*random_case(rng, 256, 8, 4), tile=64)


def test_counts_sum_to_n():
    rng = np.random.default_rng(3)
    points, centers = random_case(rng, 4096, 16, 7)
    _, counts, _ = kmeans_assign(points, centers, tile=512)
    assert float(jnp.sum(counts)) == 4096.0


def test_indivisible_tile_raises():
    rng = np.random.default_rng(4)
    points, centers = random_case(rng, 100, 8, 4)
    with pytest.raises(ValueError, match="not divisible"):
        kmeans_assign(points, centers, tile=64)


def test_identical_points_single_cluster():
    # All points identical -> all assigned to the nearest center, inertia
    # equals n * distance to it.
    points = jnp.ones((512, 8), dtype=jnp.float32)
    centers = jnp.stack([jnp.ones(8), jnp.zeros(8)]).astype(jnp.float32)
    sums, counts, inertia = kmeans_assign(points, centers, tile=128)
    assert float(counts[0]) == 512.0 and float(counts[1]) == 0.0
    np.testing.assert_allclose(inertia, 0.0, atol=1e-3)


def test_update_matches_ref():
    rng = np.random.default_rng(5)
    sums = jnp.asarray(rng.standard_normal((20, 32)), dtype=jnp.float32)
    counts = jnp.asarray(rng.integers(0, 50, 20), dtype=jnp.float32)
    old = jnp.asarray(rng.standard_normal((20, 32)), dtype=jnp.float32)
    (new,) = model.kmeans_update(sums, counts, old)
    np.testing.assert_allclose(new, kmeans_update_ref(sums, counts, old), rtol=1e-6)


def test_update_keeps_empty_cluster_center():
    sums = jnp.zeros((3, 4), dtype=jnp.float32)
    counts = jnp.array([0.0, 2.0, 0.0], dtype=jnp.float32)
    old = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    (new,) = model.kmeans_update(sums, counts, old)
    np.testing.assert_allclose(new[0], old[0])
    np.testing.assert_allclose(new[2], old[2])
    np.testing.assert_allclose(new[1], jnp.zeros(4))


# Hypothesis sweep: shapes (multiples of the tile), center counts, seeds.
@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 6),
    tile=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([4, 8, 16, 32]),
    k=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(tiles, tile, d, k, seed):
    rng = np.random.default_rng(seed)
    check(*random_case(rng, tiles * tile, d, k), tile=tile)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_scale_invariance_of_assignment(scale, seed):
    # Scaling all coordinates scales sums linearly and counts not at all.
    rng = np.random.default_rng(seed)
    points, centers = random_case(rng, 512, 8, 5)
    s1, c1, _ = kmeans_assign(points, centers, tile=128)
    s2, c2, _ = kmeans_assign(points * scale, centers * scale, tile=128)
    np.testing.assert_allclose(c1, c2)
    np.testing.assert_allclose(s2, s1 * scale, rtol=1e-4, atol=1e-3)
