//! §IV-B layout migration: rewrite the replica layout after ANY
//! communicator reshape — shrink (`p' < p`), substitution (`p' = p` with
//! spares seated in the dead ranks' positions), or grow (`p' > p`).
//!
//! The paper's headline capability beyond fast reload is *shrinking
//! recovery* — "we also support shrinking recovery instead of recovery
//! using spare compute nodes". Loading lost shards onto survivors
//! ([`crate::restore::load`]) restores the *application's* data, but the
//! *replica store* keeps addressing the dead world: failed ranks linger in
//! the §IV-A layout, §IV-E repair re-replicates onto probing-sequence
//! homes, and every later load pays the post-repair fallback route. This
//! module closes the loop: after an `ulfm` primitive (`shrink`,
//! `substitute`, or `grow`) produces the `RankMap` of the `p'`-member
//! communicator, [`ReStore::rebalance`](crate::restore::ReStore::rebalance)
//!
//! 1. **reshapes** the distribution to `p'`
//!    ([`Distribution::reshaped`]) — the permuted block ID space, the
//!    Feistel permutation, and the precomputed unit→slot placement index
//!    carry over by `Arc`; only the slice partition and copy stride change,
//!    so the new layout is bit-identical to a fresh balanced construction
//!    ([`Distribution::new_balanced`]) at `p'` (golden-tested). Slices are
//!    **balanced unequal** (`⌊n/p'⌋`/`⌈n/p'⌉` blocks, closed-form
//!    boundaries), so ANY survivor count with `r ≤ p'` is feasible — a
//!    16 → 13 kill wave rebalances instead of acknowledging;
//! 2. **plans a minimal migration** ([`plan_rebalance`]) in permuted-slot
//!    space: the permuted ID range `[0, n)` is walked over the interval
//!    lattice of old and new slice boundaries — O(p + p') intervals, each
//!    boundary a closed-form prefix-sum lookup
//!    ([`Distribution::slice_start`]) — and only intervals whose
//!    destination is **not** already an alive current holder move; sources
//!    are drawn from the reverse [`HolderIndex`] round-robin across the
//!    current holders (the §IV-E Distribution-B style spread). Data
//!    already in place is retained with a local copy, never sent;
//! 3. **executes** the schedule zero-copy in execution mode — each interval
//!    is written straight from the source slice into the destination's
//!    pre-sized new slice (sized per slice from the balanced partition)
//!    via [`PeStore::write_from`] — and charges one modeled sparse
//!    all-to-all [`PhaseCost`] (plus the local-copy term for retained
//!    bytes) in both modes;
//! 4. **atomically swaps** the new distribution, rank translation
//!    (`RankMap::new_to_old`), stores, and holder index in under the
//!    cluster's bumped epoch. `submit`/`load`/`repair` validate their
//!    layout epoch against `Cluster::epoch`, so a reshape can never be
//!    silently ignored. The swap also drops any in-flight `resubmit`
//!    staging (it addressed the old layout); the dataset's *committed*
//!    version migrates, and the epoch bump makes a staged-but-uncommitted
//!    checkpoint abort cleanly back to it
//!    ([`crate::error::Error::ResubmitAborted`]).
//!
//! The same lattice walk covers every map shape: a **substitution** map
//! (`p' = p`, a spare seated in a dead rank's position) degenerates to a
//! repair-shaped transfer — slice boundaries are unchanged, so only the
//! dead rank's intervals move, straight onto the spare — and a **grow**
//! map (`p' > p`, feasible since `reshape_feasible` only needs
//! `r ≤ p' ≤ n`) redistributes onto the widened world exactly as a fresh
//! balanced construction would place it. The policy layer choosing
//! between them is `restore::policy`.
//!
//! After a rebalance every slot again has exactly `r` replicas on *alive*
//! PEs in §IV-A positions: the IDL probability returns to the fresh
//! `p_idl(p', r, f)` level (§IV-D — see `examples/replica_repair.rs`) and
//! steady-state loads take the deterministic-holder fast path with no
//! post-repair fallback.
//!
//! Memory transiently doubles during the swap (old + new stores coexist),
//! mirroring the §IV-C "doubled during submission" observation for submit.
//!
//! Only when fewer than `r` PEs survive
//! ([`Distribution::reshape_feasible`]) does the layout become
//! unrepresentable; applications then stay in the dead world via
//! `ReStore::acknowledge_shrink` + §IV-E repair.
//! `ReStore::rebalance_or_acknowledge` packages that policy — and, since
//! a stale [`RankMap`] from an earlier shrink could silently steer it,
//! validates the map against the cluster up front
//! (`Error::StaleRankMap`).

use crate::error::{Error, Result};
use crate::restore::distribution::Distribution;
use crate::restore::registry::Dataset;
use crate::restore::store::{HolderIndex, PeStore, SliceBuf};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;
use crate::simnet::ulfm::RankMap;

/// One planned migration: copy the permuted interval
/// `[perm_start, perm_start + blocks)` from `src` to `dst` (cluster ranks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationTransfer {
    pub perm_start: u64,
    pub blocks: u64,
    pub src: usize,
    pub dst: usize,
}

/// Report of a [`ReStore::rebalance`].
#[derive(Debug, Clone, Default)]
pub struct RebalanceReport {
    /// World size of the new layout (`p'`).
    pub new_world: usize,
    /// Number of migration transfers executed (remote interval copies).
    pub transfers: usize,
    /// Bytes moved over the network — exactly the intervals whose new
    /// holder was not already an alive current holder (minimality is
    /// property-tested against a store-diff oracle).
    pub migrated_bytes: u64,
    /// Bytes retained in place (destination already held them; local copy
    /// into the new slice buffer, no message).
    pub kept_bytes: u64,
    /// Local-copy term + the migration sparse all-to-all.
    pub cost: PhaseCost,
}

/// Plan the minimal migration from `old_dist`'s layout (with `holders` the
/// *current* reverse holder index, including §IV-E repair-created replicas)
/// to `new_dist`'s layout over the survivors.
///
/// Walks the permuted ID space over the lattice of old and new slice
/// boundaries. For each interval, the new holder set is
/// `{to_cluster[new_dist.holder(·, k)]}`; destinations that are already
/// alive current holders are reported through `on_keep(pe, perm_start,
/// blocks)` (retained in place), every other destination becomes one
/// [`MigrationTransfer`] pushed to `out`, sourced round-robin across the
/// interval's alive current holders. Errors with
/// [`Error::IrrecoverableDataLoss`] when an interval has no alive holder
/// left.
///
/// Planning is allocation-frugal by construction — a fixed number of
/// scratch vectors regardless of world size (asserted by
/// `rust/tests/alloc_counts.rs`); `out` is caller-provided for reuse.
pub fn plan_rebalance(
    old_dist: &Distribution,
    new_dist: &Distribution,
    holders: &HolderIndex,
    alive: impl Fn(usize) -> bool,
    to_cluster: &[u32],
    mut on_keep: impl FnMut(usize, u64, u64),
    out: &mut Vec<MigrationTransfer>,
) -> Result<()> {
    let n = old_dist.n_blocks();
    debug_assert_eq!(n, new_dist.n_blocks(), "rebalance must preserve the block space");
    debug_assert_eq!(to_cluster.len(), new_dist.world());
    debug_assert_eq!(holders.slots(), old_dist.world());
    let r = new_dist.replicas();
    // Round-robin source cursor per old slot, advanced across all of the
    // slot's intervals and destinations, spreading migration reads evenly
    // over the current holders.
    let mut rr: Vec<u32> = vec![0; old_dist.world()];
    let mut srcs: Vec<usize> = Vec::with_capacity(r + 4);
    let mut dsts: Vec<usize> = Vec::with_capacity(r);
    let mut cur = 0u64;
    while cur < n {
        // Next boundary of the old/new slice-interval lattice: both sides
        // are closed-form prefix-sum lookups (slice_start/slice_end), so
        // unequal slices cost the same O(1) per interval as the former
        // fixed-stride division.
        let old_slot = old_dist.slice_of(cur);
        let new_slot = new_dist.slice_of(cur);
        let stop = old_dist.slice_end(old_slot).min(new_dist.slice_end(new_slot)).min(n);
        let len = stop - cur;
        srcs.clear();
        srcs.extend(
            holders
                .holders_of(old_slot)
                .iter()
                .map(|&pe| pe as usize)
                .filter(|&pe| alive(pe)),
        );
        if srcs.is_empty() {
            // Every current holder of this interval is dead: the §IV-D IDL
            // event. Report the first lost permutation unit in original-ID
            // terms, like the load path does.
            let s_pr = old_dist.perm_range_blocks();
            let ulen = len.min(s_pr - cur % s_pr);
            let orig = old_dist.unpermute_block(cur);
            // Planning is dataset-agnostic; callers re-tag with the real
            // dataset id (`Error::tag_dataset`).
            return Err(Error::IrrecoverableDataLoss {
                dataset: crate::restore::registry::DatasetId::FIRST,
                start: orig,
                end: orig + ulen,
            });
        }
        dsts.clear();
        for k in 0..r {
            dsts.push(to_cluster[new_dist.holder(cur, k)] as usize);
        }
        for &dst in &dsts {
            // `holders_of` lists are sorted ascending and alive-filtering
            // preserves order, so membership is a binary search.
            if srcs.binary_search(&dst).is_ok() {
                on_keep(dst, cur, len);
            } else {
                let pick = rr[old_slot] as usize % srcs.len();
                rr[old_slot] = rr[old_slot].wrapping_add(1);
                out.push(MigrationTransfer {
                    perm_start: cur,
                    blocks: len,
                    src: srcs[pick],
                    dst,
                });
            }
        }
        cur = stop;
    }
    Ok(())
}

/// A fully planned §IV-B reshape of one dataset (shrink, substitution, or
/// grow): everything the fused executor needs to charge and apply the
/// layout rewrite. Planning is pure (no clock advance, no store mutation),
/// so a plan can be discarded — which is exactly what the
/// `rebalance_or_acknowledge` policy does when a dataset's plan hits
/// [`Error::IrrecoverableDataLoss`].
pub(crate) struct ReshapePlan {
    new_dist: Distribution,
    to_cluster: Vec<u32>,
    /// Sorted by (src, dst, perm_start) — the per-pair coalescing order.
    transfers: Vec<MigrationTransfer>,
    /// Retained intervals to replay locally (execution mode only).
    keeps: Vec<(usize, u64, u64)>,
    /// Indexed by cluster rank; the §IV-C-style transient local copies.
    kept_bytes_per_pe: Vec<u64>,
}

/// Charge the fused §IV-B migration for a set of dataset plans: ONE local
/// copy term (per-PE kept bytes summed across datasets, slowest PE billed)
/// followed by ONE sparse all-to-all whose per-(src, dst) messages
/// concatenate every dataset's intervals for that pair (bytes summed, one
/// pack/unpack fragment per interval per dataset). With a single plan this
/// is charge-identical to the historical single-dataset `rebalance`.
pub(crate) fn charge_reshape_plans(
    cluster: &mut Cluster,
    plans: &[(&ReshapePlan, u64)],
) -> Result<(PhaseCost, PhaseCost)> {
    // Local copies: every survivor re-materializes its kept data of ALL
    // datasets in the new slice buffers, in parallel across PEs — bill the
    // slowest PE's total.
    let mut max_local = 0u64;
    if let Some((first, _)) = plans.first() {
        for pe in 0..first.kept_bytes_per_pe.len() {
            let total: u64 = plans.iter().map(|(p, _)| p.kept_bytes_per_pe[pe]).sum();
            max_local = max_local.max(total);
        }
    }
    let local_cost = PhaseCost::local_copy(cluster.network(), max_local);
    cluster.advance(&local_cost);

    // ONE migration sparse all-to-all across all datasets: each plan's
    // transfers are sorted by (src, dst, perm_start), so a k-way merge on
    // the (src, dst) key visits every pair once, in order.
    let mut phase = cluster.phase();
    let mut idx: Vec<usize> = vec![0; plans.len()];
    loop {
        let mut pair: Option<(usize, usize)> = None;
        for (d, (plan, _)) in plans.iter().enumerate() {
            if let Some(t) = plan.transfers.get(idx[d]) {
                let key = (t.src, t.dst);
                if pair.map_or(true, |best| key < best) {
                    pair = Some(key);
                }
            }
        }
        let Some((src, dst)) = pair else { break };
        let mut bytes = 0u64;
        for (d, (plan, bs)) in plans.iter().enumerate() {
            let mut i = idx[d];
            let mut intervals = 0u64;
            while i < plan.transfers.len()
                && plan.transfers[i].src == src
                && plan.transfers[i].dst == dst
            {
                bytes += plan.transfers[i].blocks * bs;
                intervals += 1;
                i += 1;
            }
            idx[d] = i;
            if intervals > 0 {
                phase.frag(src, intervals);
                phase.frag(dst, intervals);
            }
        }
        phase.add(src, dst, bytes)?;
    }
    Ok((local_cost, phase.commit()))
}

impl Dataset {
    /// Plan this dataset's §IV-B reshape onto the `map`'s `p'`-member
    /// communicator (a shrink, substitution, or grow map alike): validates
    /// the handshake (preceding `ulfm` epoch bump, current map, feasible
    /// `p'`) and computes the minimal migration — no clock advance, no
    /// store mutation. A kill wave that wiped a whole holder set surfaces
    /// as [`Error::IrrecoverableDataLoss`] here — a failure path
    /// `rebalance_or_acknowledge` deliberately drives before degrading to
    /// acknowledge — so it must cost O(p + p') planning work, not an
    /// r·n·bs destination-buffer memset that is then thrown away.
    /// Retained intervals are recorded for replay once the buffers exist
    /// (they are O(r·(p + p')) tuples, nothing like the payload).
    pub(crate) fn plan_reshape(&self, cluster: &Cluster, map: &RankMap) -> Result<ReshapePlan> {
        self.ensure_submitted()?;
        if cluster.epoch() <= self.epoch() {
            return Err(Error::Config(format!(
                "rebalance requires a preceding ulfm shrink/substitute/grow: \
                 store epoch {}, cluster epoch {}",
                self.epoch(),
                cluster.epoch()
            )));
        }
        map.validate_against(cluster)?;
        let new_dist = self.distribution().reshaped(map.new_world())?;
        let to_cluster: Vec<u32> = map.new_to_old.iter().map(|&o| o as u32).collect();

        let execution = self.is_execution_mode();
        let bs = self.config().block_size as u64;
        // Per-cluster-rank accounting: the store array spans the whole
        // machine (spare pool included), and migration endpoints can be
        // activated spares past the configured base world.
        let world = self.stores().len();

        let mut transfers: Vec<MigrationTransfer> = Vec::new();
        let mut keeps: Vec<(usize, u64, u64)> = Vec::new();
        let mut kept_bytes_per_pe: Vec<u64> = vec![0; world];
        plan_rebalance(
            self.distribution(),
            &new_dist,
            self.holder_index(),
            |pe| cluster.is_alive(pe),
            &to_cluster,
            |pe, perm_start, blocks| {
                kept_bytes_per_pe[pe] += blocks * bs;
                if execution {
                    keeps.push((pe, perm_start, blocks));
                }
            },
            &mut transfers,
        )
        .map_err(|e| e.tag_dataset(self.id()))?;
        // Per-pair coalescing order for the (possibly fused) charge.
        transfers.sort_unstable_by_key(|t| (t.src, t.dst, t.perm_start));

        Ok(ReshapePlan { new_dist, to_cluster, transfers, keeps, kept_bytes_per_pe })
    }

    /// Execute a planned reshape: build the new slice buffers, replay the
    /// retained intervals, run the migration zero-copy, and atomically
    /// swap the layout in under the cluster's epoch. The caller has
    /// already charged the phases (`charge_reshape_plans`) — `shared_cost`
    /// is recorded in the report (the fused local + migration cost, shared
    /// by every dataset rebalanced in the same handshake).
    ///
    /// Every source interval — retained AND migrated — is
    /// checksum-verified up front, before a single byte moves: a reshape
    /// must never launder silent corruption into a fresh layout whose
    /// recomputed checksums would declare the rotten bytes healthy. A
    /// mismatch aborts with
    /// [`Error::CorruptBlock`](crate::error::Error::CorruptBlock) and,
    /// because only the not-yet-installed new store set is ever written,
    /// the old layout stays byte-intact (the swap is atomic-on-success) —
    /// run `Dataset::scrub`, then rebalance again.
    pub(crate) fn apply_reshape(
        &mut self,
        cluster: &Cluster,
        plan: ReshapePlan,
        shared_cost: PhaseCost,
    ) -> Result<RebalanceReport> {
        let ReshapePlan { new_dist, to_cluster, transfers, keeps, kept_bytes_per_pe } = plan;
        let execution = self.is_execution_mode();
        let bs = self.config().block_size;
        let r = new_dist.replicas();

        // Ingest verification first — all of it before any new-store write,
        // so the error path does no wasted buffer work.
        if execution {
            let old_dist = self.distribution();
            let corrupt = |pe: usize, perm_start: u64, blocks: u64| {
                self.stores()[pe].verify(perm_start, blocks).map(|y| Error::CorruptBlock {
                    dataset: self.id(),
                    block: old_dist.unpermute_block(y),
                    holder: pe,
                })
            };
            for &(pe, perm_start, blocks) in &keeps {
                if let Some(e) = corrupt(pe, perm_start, blocks) {
                    return Err(e);
                }
            }
            for t in &transfers {
                if let Some(e) = corrupt(t.src, t.perm_start, t.blocks) {
                    return Err(e);
                }
            }
        }
        // One (mostly empty) store shell per machine slot, so activated
        // spares have a slot to receive their migrated slices.
        let world = self.stores().len();

        // Pre-create every survivor's r new slices (zeroed in execution
        // mode, sized per slice — the balanced partition has ⌈n/p'⌉ and
        // ⌊n/p'⌋ slices, each length a closed-form lookup) and the new
        // reverse holder index — exactly what a fresh submit at p' would
        // lay out. The zero fill is redundant work in principle (the keep
        // + migration writes below cover every byte; the minimality tests
        // assert kept + migrated == stored), but pre-sized initialized
        // buffers are what `write_from` requires and what submit does —
        // trading one memset pass for not reasoning about uninitialized
        // memory.
        let mut new_stores: Vec<PeStore> = (0..world).map(|_| PeStore::new(bs)).collect();
        let mut new_index = HolderIndex::new(new_dist.world());
        for (j, &pe) in to_cluster.iter().enumerate() {
            let pe = pe as usize;
            for k in 0..r {
                let range = new_dist.stored_slice(j, k);
                let slot = new_dist.slice_of(range.start);
                let slice_bytes = (range.len() * bs as u64) as usize;
                let buf = if execution {
                    SliceBuf::Real(vec![0u8; slice_bytes])
                } else {
                    SliceBuf::Virtual(slice_bytes as u64)
                };
                new_stores[pe].insert(range, buf);
                new_index.insert(slot, pe);
            }
        }

        // Replay the retained intervals into the new slices (zero-copy:
        // one write_from straight out of the old slice each).
        for &(pe, perm_start, blocks) in &keeps {
            let bytes = self.stores()[pe]
                .read(perm_start, blocks)
                .expect("execution-mode store must hold real bytes");
            new_stores[pe].write_from(perm_start, bytes);
        }

        // Execute the migration zero-copy (old stores are read-only here;
        // destinations live in the not-yet-installed new store set, so a
        // same-call destination can never be read as a source).
        let mut migrated = 0u64;
        for t in &transfers {
            migrated += t.blocks * bs as u64;
            if execution {
                let bytes = self.stores()[t.src]
                    .read(t.perm_start, t.blocks)
                    .expect("execution-mode store must hold real bytes");
                new_stores[t.dst].write_from(t.perm_start, bytes);
            }
        }

        let report = RebalanceReport {
            new_world: new_dist.world(),
            transfers: transfers.len(),
            migrated_bytes: migrated,
            kept_bytes: kept_bytes_per_pe.iter().sum(),
            cost: shared_cost,
        };
        // Atomic swap: distribution, rank translation, stores, and holder
        // index become current together, under the cluster's epoch. Dead
        // PEs' old stores are dropped with the old store set (the former
        // standalone `drop_pe` reclaim, folded in).
        self.install_layout(cluster, new_dist, to_cluster, new_stores, new_index);
        Ok(report)
    }

    /// §IV-B layout migration of THIS dataset: rewrite the layout over the
    /// `map`'s `p'`-member communicator — a shrink, substitution (spare
    /// seated in a dead rank's position), or grow map alike. Requires a
    /// preceding `ulfm` epoch bump (the cluster epoch must be ahead of the
    /// dataset's) and a feasible `p'`
    /// ([`Distribution::reshape_feasible`]); on any error the old layout
    /// stays fully intact (the swap is atomic-on-success). Registries with
    /// several datasets should prefer the fused
    /// [`ReStore::rebalance_or_acknowledge`](crate::restore::ReStore::rebalance_or_acknowledge),
    /// which adopts the reshape for every dataset under one epoch with one
    /// merged migration all-to-all; policy selection (shrink vs substitute
    /// vs shrink-then-regrow) lives in
    /// [`policy`](crate::restore::policy).
    pub fn rebalance(&mut self, cluster: &mut Cluster, map: &RankMap) -> Result<RebalanceReport> {
        let plan = self.plan_reshape(cluster, map)?;
        let bs = self.config().block_size as u64;
        let (local_cost, net_cost) = charge_reshape_plans(cluster, &[(&plan, bs)])?;
        self.apply_reshape(cluster, plan, local_cost.then(net_cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::block::{BlockRange, RangeSet};
    use crate::restore::load::scatter_requests_for_ranges;
    use crate::restore::{LoadRequest, ReStore};
    use crate::simnet::ulfm;

    fn make_shards(world: usize, bytes: usize) -> Vec<Vec<u8>> {
        (0..world)
            .map(|pe| (0..bytes).map(|i| (pe * 37 + i * 5) as u8).collect())
            .collect()
    }

    fn build(
        p: usize,
        bpp: usize,
        r: usize,
        s_pr: Option<usize>,
        execution: bool,
    ) -> (Cluster, ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(p, bpp * 8);
        if execution {
            rs.submit(&mut cluster, &shards).unwrap();
        } else {
            rs.submit_virtual(&mut cluster).unwrap();
        }
        (cluster, rs, shards)
    }

    /// Kill 2 PEs of every group at p=16, r=4 (ranks 0..8): survivors 8..15
    /// keep 2 alive copies per slot, and p' = 8 admits the §IV-A layout.
    const HALF_KILLS: [usize; 8] = [0, 1, 2, 3, 4, 5, 6, 7];

    /// Golden reference: fresh `Distribution::new(p')` + resubmit of the
    /// re-sharded original data on a brand-new p'-PE cluster.
    fn fresh_resubmit(
        p_new: usize,
        s_pr: Option<usize>,
        r: usize,
        shards: &[Vec<u8>],
    ) -> (Cluster, ReStore) {
        let global: Vec<u8> = shards.iter().flatten().copied().collect();
        let shard_bytes = global.len() / p_new;
        let new_shards: Vec<Vec<u8>> =
            (0..p_new).map(|j| global[j * shard_bytes..(j + 1) * shard_bytes].to_vec()).collect();
        let cfg = RestoreConfig::builder(p_new, 8, shard_bytes / 8)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p_new, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit(&mut cluster, &new_shards).unwrap();
        (cluster, rs)
    }

    #[test]
    fn rebalanced_stores_match_fresh_submit_at_p_prime() {
        for s_pr in [Some(16usize), None] {
            let (mut cluster, mut rs, shards) = build(16, 64, 4, s_pr, true);
            cluster.kill(&HALF_KILLS);
            let (_failed, map, _cost) = ulfm::recover(&mut cluster);
            let report = rs.rebalance(&mut cluster, &map).unwrap();
            assert_eq!(report.new_world, 8, "s_pr {s_pr:?}");
            assert!(report.migrated_bytes > 0);

            let (_fc, fresh) = fresh_resubmit(8, s_pr, 4, &shards);
            for j in 0..8usize {
                let ours = rs.stores()[map.new_to_old[j]].slices();
                let want = fresh.stores()[j].slices();
                assert_eq!(ours.len(), want.len(), "s_pr {s_pr:?}: new rank {j} slice count");
                for (g, w) in ours.iter().zip(want) {
                    assert_eq!(g.range, w.range, "s_pr {s_pr:?}: new rank {j}");
                    let (SliceBuf::Real(gb), SliceBuf::Real(wb)) = (&g.buf, &w.buf) else {
                        panic!("execution mode must store real bytes");
                    };
                    assert_eq!(gb, wb, "s_pr {s_pr:?}: new rank {j} slice {:?}", g.range);
                }
            }
            // dead PEs' stores were reclaimed with the swap
            for &pe in &HALF_KILLS {
                assert!(rs.stores()[pe].slices().is_empty(), "dead PE {pe} still holds data");
            }
            // holder index: ours (cluster ranks) == fresh (new ranks)
            // translated through the monotone new_to_old map
            for slot in 0..8usize {
                let want: Vec<u32> = fresh
                    .holder_index()
                    .holders_of(slot)
                    .iter()
                    .map(|&j| map.new_to_old[j as usize] as u32)
                    .collect();
                assert_eq!(rs.holder_index().holders_of(slot), &want[..], "slot {slot}");
            }
            // ...and matches a from-scratch rebuild at the new slot count
            assert_eq!(
                *rs.holder_index(),
                HolderIndex::rebuild(rs.stores(), rs.distribution()),
                "s_pr {s_pr:?}: holder index drifted"
            );
        }
    }

    /// Fresh-layout store oracle for ANY (p', possibly unequal-slice)
    /// distribution: the permuted bytes each (new rank, copy) slice must
    /// hold, derived block by block from the original global data.
    fn fresh_layout_stores(
        dist: &Distribution,
        shards: &[Vec<u8>],
        bs: usize,
    ) -> Vec<Vec<(crate::restore::block::BlockRange, Vec<u8>)>> {
        let global: Vec<u8> = shards.iter().flatten().copied().collect();
        (0..dist.world())
            .map(|j| {
                let mut slices: Vec<(crate::restore::block::BlockRange, Vec<u8>)> = (0..dist
                    .replicas())
                    .map(|k| {
                        let range = dist.stored_slice(j, k);
                        let mut buf = Vec::with_capacity((range.len() as usize) * bs);
                        for y in range.start..range.end {
                            let x = dist.unpermute_block(y) as usize;
                            buf.extend_from_slice(&global[x * bs..(x + 1) * bs]);
                        }
                        (range, buf)
                    })
                    .collect();
                slices.sort_by_key(|(r, _)| r.start);
                slices
            })
            .collect()
    }

    /// The tentpole scenario: a 16 → 13 kill wave (a non-dividing survivor
    /// count the equal-slice layout had to acknowledge) now rebalances,
    /// and the result is byte-identical to a fresh balanced layout at
    /// p' = 13 — stores AND holder index, modulo the rank translation.
    #[test]
    fn non_dividing_rebalance_matches_fresh_balanced_layout() {
        for s_pr in [Some(16usize), None] {
            let (mut cluster, mut rs, shards) = build(16, 64, 4, s_pr, true);
            // kill 3 PEs from distinct §IV-D groups (stride 4): no IDL
            cluster.kill(&[0, 1, 2]);
            let (_failed, map, _cost) = ulfm::recover(&mut cluster);
            assert!(rs.can_rebalance(&cluster), "s_pr {s_pr:?}: p' = 13 must be feasible");
            let report = rs.rebalance(&mut cluster, &map).unwrap();
            assert_eq!(report.new_world, 13, "s_pr {s_pr:?}");
            // every stored byte is accounted for: kept + migrated == r·n·bs
            assert_eq!(
                report.kept_bytes + report.migrated_bytes,
                4 * 1024 * 8,
                "s_pr {s_pr:?}"
            );

            let dist = rs.distribution().clone();
            assert_eq!(dist.world(), 13);
            assert!(!dist.equal_slices()); // 1024 = 13·78 + 10
            assert_eq!(dist.max_slice_blocks(), 79);
            let want = fresh_layout_stores(&dist, &shards, 8);
            for j in 0..13usize {
                let ours = rs.stores()[map.new_to_old[j]].slices();
                assert_eq!(ours.len(), want[j].len(), "s_pr {s_pr:?}: new rank {j}");
                for (g, (wrange, wbytes)) in ours.iter().zip(&want[j]) {
                    assert_eq!(g.range, *wrange, "s_pr {s_pr:?}: new rank {j}");
                    let SliceBuf::Real(gb) = &g.buf else {
                        panic!("execution mode must store real bytes");
                    };
                    assert_eq!(gb, wbytes, "s_pr {s_pr:?}: new rank {j} slice {wrange:?}");
                }
            }
            // holder index equals a from-scratch rebuild over the new lattice
            assert_eq!(
                *rs.holder_index(),
                HolderIndex::rebuild(rs.stores(), rs.distribution()),
                "s_pr {s_pr:?}: holder index drifted"
            );
            // and dead PEs were reclaimed with the swap
            for pe in [0usize, 1, 2] {
                assert!(rs.stores()[pe].slices().is_empty());
            }

            // the lost shards still load bit-exactly in the new layout
            let survivors = cluster.survivors();
            let mut gained: Vec<(usize, RangeSet)> = Vec::new();
            for (i, dead) in [0u64, 1, 2].into_iter().enumerate() {
                gained.push((
                    survivors[i % survivors.len()],
                    RangeSet::new(vec![BlockRange::new(dead * 64, (dead + 1) * 64)]),
                ));
            }
            let reqs = scatter_requests_for_ranges(&gained);
            let out = rs.load(&mut cluster, &reqs).unwrap();
            for (req, shard) in reqs.iter().zip(&out.shards) {
                let mut want = Vec::new();
                for range in req.ranges.ranges() {
                    for x in range.start..range.end {
                        let pe = (x / 64) as usize;
                        let off = ((x % 64) * 8) as usize;
                        want.extend_from_slice(&shards[pe][off..off + 8]);
                    }
                }
                assert_eq!(shard.bytes.as_deref().unwrap(), &want[..], "s_pr {s_pr:?}");
            }
        }
    }

    #[test]
    fn migration_moves_only_changed_holder_sets() {
        for s_pr in [Some(16usize), None] {
            let (mut cluster, mut rs, _) = build(16, 64, 4, s_pr, true);
            cluster.kill(&HALF_KILLS);
            // store-diff oracle: bytes each survivor must receive = its new
            // slices minus what it already held before the rebalance
            let pre_held: Vec<Vec<BlockRange>> = (0..16)
                .map(|pe| rs.stores()[pe].slices().iter().map(|s| s.range).collect())
                .collect();
            let (_failed, map, _) = ulfm::recover(&mut cluster);
            let report = rs.rebalance(&mut cluster, &map).unwrap();

            let mut expected = 0u64;
            for &pe in &map.new_to_old {
                for s in rs.stores()[pe].slices() {
                    let mut missing = s.range.len();
                    for old in &pre_held[pe] {
                        if let Some(overlap) = s.range.intersect(old) {
                            missing -= overlap.len();
                        }
                    }
                    expected += missing * 8;
                }
            }
            assert_eq!(report.migrated_bytes, expected, "s_pr {s_pr:?}");
            // kept + migrated account for every stored byte of the new world
            let total = 8u64 * 4 * 128 * 8;
            assert_eq!(report.kept_bytes + report.migrated_bytes, total, "s_pr {s_pr:?}");
        }
    }

    #[test]
    fn rebalance_requires_shrink_and_current_map() {
        let (mut cluster, mut rs, _) = build(16, 64, 4, Some(16), false);
        let map = ulfm::RankMap::identity(16);
        // no shrink yet -> epoch gate refuses
        assert!(matches!(rs.rebalance(&mut cluster, &map), Err(Error::Config(_))));

        cluster.kill(&HALF_KILLS);
        let (_failed, map, _) = ulfm::recover(&mut cluster);
        // the shrink bumped the epoch: routing is now refused until the
        // store adopts the new world
        let reqs = vec![LoadRequest {
            pe: 8,
            ranges: RangeSet::new(vec![BlockRange::new(0, 16)]),
        }];
        assert!(matches!(
            rs.load(&mut cluster, &reqs),
            Err(Error::StaleEpoch { store_epoch: 0, cluster_epoch: 1 })
        ));
        assert!(matches!(
            rs.repair_replicas(&mut cluster, crate::restore::repair::RepairScheme::DoubleHashing),
            Err(Error::StaleEpoch { .. })
        ));

        // a stale map (second failure after the shrink) is rejected
        let mut cluster2 = cluster.clone();
        cluster2.kill(&[15]);
        ulfm::shrink(&mut cluster2);
        assert!(rs.rebalance(&mut cluster2, &map).is_err());

        // the real map works, and routing resumes
        rs.rebalance(&mut cluster, &map).unwrap();
        assert_eq!(rs.epoch(), cluster.epoch());
        rs.load(&mut cluster, &reqs).unwrap();
    }

    #[test]
    fn post_rebalance_loads_are_exact_and_fast_path() {
        let (mut cluster, mut rs, shards) = build(16, 64, 4, Some(16), true);
        cluster.kill(&HALF_KILLS);
        let (failed, map, _) = ulfm::recover(&mut cluster);
        rs.rebalance(&mut cluster, &map).unwrap();

        // fast path: every slot has exactly r alive holders in the
        // deterministic §IV-A positions of the new layout — the load path
        // never needs the post-repair fallback
        let dist = rs.distribution().clone();
        for slot in 0..dist.world() {
            let holders = rs.holder_index().holders_of(slot);
            assert_eq!(holders.len(), 4, "slot {slot}");
            let start = dist.slice_start(slot);
            let mut det: Vec<u32> =
                (0..4).map(|k| rs.cluster_rank(dist.holder(start, k)) as u32).collect();
            det.sort_unstable();
            assert_eq!(holders, &det[..], "slot {slot} holders are not the §IV-A set");
            for &pe in holders {
                assert!(cluster.is_alive(pe as usize));
            }
        }

        // the failed PEs' original shards load bit-exactly, scattered over
        // the survivors
        let survivors = cluster.survivors();
        let mut gained: Vec<(usize, RangeSet)> = Vec::new();
        for (i, &dead) in failed.iter().enumerate() {
            let start = dead as u64 * 64;
            gained.push((
                survivors[i % survivors.len()],
                RangeSet::new(vec![BlockRange::new(start, start + 64)]),
            ));
        }
        let reqs = scatter_requests_for_ranges(&gained);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            let mut want = Vec::new();
            for range in req.ranges.ranges() {
                for x in range.start..range.end {
                    let pe = (x / 64) as usize;
                    let off = ((x % 64) * 8) as usize;
                    want.extend_from_slice(&shards[pe][off..off + 8]);
                }
            }
            assert_eq!(shard.bytes.as_deref().unwrap(), &want[..], "PE {}", req.pe);
        }
    }

    /// Regressions around post-rebalance loads: (a) the LeastLoaded
    /// per-server byte table is indexed by *cluster* ranks, which keep
    /// their original numbering after the distribution shrinks to p' —
    /// sizing it by dist.world() panicked on the first post-rebalance
    /// load; (b) `scatter_requests` must describe the *submit-time* shard
    /// of a dead rank (here the dead ranks 8..16 don't even exist in the
    /// p' = 8 world, so the current distribution's shard_of would address
    /// past the block space). Every policy must route the lost shards
    /// bit-exactly.
    #[test]
    fn post_rebalance_load_works_under_every_policy() {
        use crate::config::ServerSelection;
        use crate::restore::load::scatter_requests;
        let kills: Vec<usize> = (8..16).collect(); // 2 per group; p' = 8
        for policy in [
            ServerSelection::Random,
            ServerSelection::LeastLoaded,
            ServerSelection::Primary,
        ] {
            let cfg = RestoreConfig::builder(16, 8, 64)
                .replicas(4)
                .perm_range_blocks(Some(16))
                .server_selection(policy)
                .build()
                .unwrap();
            let mut cluster = Cluster::new_execution(16, 4);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            let shards = make_shards(16, 64 * 8);
            rs.submit(&mut cluster, &shards).unwrap();
            cluster.kill(&kills);
            let (failed, map, _) = ulfm::recover(&mut cluster);
            rs.rebalance(&mut cluster, &map).unwrap();
            let reqs = scatter_requests(&rs, &cluster, &failed);
            let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
            assert_eq!(total, 8 * 64, "{policy:?}: scatter must cover the lost shards");
            let out = rs.load(&mut cluster, &reqs).unwrap();
            for (req, shard) in reqs.iter().zip(&out.shards) {
                let mut want = Vec::new();
                for range in req.ranges.ranges() {
                    for x in range.start..range.end {
                        let pe = (x / 64) as usize;
                        let off = ((x % 64) * 8) as usize;
                        want.extend_from_slice(&shards[pe][off..off + 8]);
                    }
                }
                assert_eq!(shard.bytes.as_deref().unwrap(), &want[..], "{policy:?}");
            }
        }
    }

    #[test]
    fn chained_shrinks_rebalance_repeatedly() {
        // 16 -> 8 -> 4, verifying layout invariants and data access at
        // every stage (including a post-rebalance §IV-E repair interop).
        let (mut cluster, mut rs, shards) = build(16, 64, 4, Some(16), true);
        cluster.kill(&HALF_KILLS);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        rs.rebalance(&mut cluster, &map).unwrap();

        // second wave: kill 4 of the 8 survivors (2 per new group)
        cluster.kill(&[8, 9, 10, 11]);
        let (_f, map2, _) = ulfm::recover(&mut cluster);
        let report = rs.rebalance(&mut cluster, &map2).unwrap();
        assert_eq!(report.new_world, 4);
        assert_eq!(
            *rs.holder_index(),
            HolderIndex::rebuild(rs.stores(), rs.distribution())
        );
        // every survivor holds r * n/p' blocks (§IV-C at the new world)
        for &pe in &map2.new_to_old {
            let blocks: u64 = rs.stores()[pe].slices().iter().map(|s| s.range.len()).sum();
            assert_eq!(blocks, 4 * 256, "PE {pe}");
        }
        // all data still loads bit-exactly
        let survivors = cluster.survivors();
        let reqs: Vec<LoadRequest> = survivors
            .iter()
            .enumerate()
            .map(|(j, &pe)| LoadRequest {
                pe,
                ranges: RangeSet::new(vec![BlockRange::new(j as u64 * 256, (j as u64 + 1) * 256)]),
            })
            .collect();
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            let mut want = Vec::new();
            for range in req.ranges.ranges() {
                for x in range.start..range.end {
                    let pe = (x / 64) as usize;
                    let off = ((x % 64) * 8) as usize;
                    want.extend_from_slice(&shards[pe][off..off + 8]);
                }
            }
            assert_eq!(shard.bytes.as_deref().unwrap(), &want[..]);
        }
    }

    #[test]
    fn rebalance_detects_idl() {
        // Kill a whole §IV-D group (plus fillers to keep p' = 8 feasible):
        // group {1, 5, 9, 13} of p=16/r=4 dies entirely -> its slots have
        // no surviving holder and the rebalance must refuse.
        let (mut cluster, mut rs, _) = build(16, 64, 4, Some(16), false);
        cluster.kill(&[1, 5, 9, 13, 0, 4, 2, 6]);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        assert!(matches!(
            rs.rebalance(&mut cluster, &map),
            Err(Error::IrrecoverableDataLoss { .. })
        ));
        // the failed rebalance left the old layout fully intact
        assert_eq!(rs.epoch(), 0);
        assert_eq!(rs.distribution().world(), 16);
    }

    /// A reshape must refuse to launder silent corruption into the new
    /// layout (whose recomputed checksums would declare the rotten bytes
    /// healthy) — and, like every other failed reshape, leave the old
    /// layout byte-intact.
    #[test]
    fn rebalance_refuses_corrupt_source_and_keeps_old_layout() {
        let (mut cluster, mut rs, shards) = build(16, 64, 4, Some(16), true);
        // Rot one bit in EVERY copy of one block: the new layout re-places
        // each block r times, each placement reading SOME current copy
        // (kept or migrated), so the reshape is guaranteed to read a
        // corrupt source whichever holder the planner draws.
        let x = 42u64;
        let (y, holders) = {
            let ds = &rs.datasets[0];
            let y = ds.dist.permute_block(x);
            (y, (0..4).map(|k| ds.cluster_rank(ds.dist.holder(y, k))).collect::<Vec<_>>())
        };
        for &pe in &holders {
            assert!(rs.datasets[0].stores[pe].corrupt_block_bit(y, 5));
        }
        cluster.kill(&HALF_KILLS);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        match rs.rebalance(&mut cluster, &map) {
            Err(Error::CorruptBlock { block, holder, .. }) => {
                assert_eq!(block, x);
                assert!(holders.contains(&holder));
            }
            other => panic!("expected CorruptBlock, got {other:?}"),
        }
        // old layout fully intact: old epoch, old world, survivor bytes
        assert_eq!(rs.epoch(), 0);
        assert_eq!(rs.distribution().world(), 16);
        assert_eq!(rs.stores()[15].slices().len(), 4);
        // heal the bits (un-flip) and the SAME map rebalances fine, ending
        // byte-identical to the never-corrupted run
        for &pe in &holders {
            assert!(rs.datasets[0].stores[pe].corrupt_block_bit(y, 5));
        }
        rs.rebalance(&mut cluster, &map).unwrap();
        let (_fc, fresh) = fresh_resubmit(8, Some(16), 4, &shards);
        for j in 0..8usize {
            let ours = rs.stores()[map.new_to_old[j]].slices();
            let want = fresh.stores()[j].slices();
            for (g, w) in ours.iter().zip(want) {
                let (SliceBuf::Real(gb), SliceBuf::Real(wb)) = (&g.buf, &w.buf) else {
                    panic!("execution mode must store real bytes");
                };
                assert_eq!(gb, wb, "new rank {j} slice {:?}", g.range);
            }
        }
    }

    #[test]
    fn acknowledge_shrink_reclaims_and_adopts_epoch() {
        // With balanced unequal slices the ONLY infeasible survivor count
        // is p' < r: p = 8, r = 4, kill 5 (≤ 3 per §IV-D group, so the
        // data survives) -> p' = 3 cannot place 4 distinct copies.
        let (mut cluster, mut rs, _) = build(8, 64, 4, Some(16), false);
        cluster.kill(&[0, 1, 2, 3, 4]);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        assert!(!rs.can_rebalance(&cluster), "p' = 3 < r = 4 must be infeasible");
        let ran = rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
        assert!(ran.is_none(), "infeasible world must fall back to acknowledge");
        assert_eq!(rs.epoch(), cluster.epoch());
        for pe in 0..5 {
            assert!(rs.stores()[pe].slices().is_empty(), "dead PE {pe} not reclaimed");
        }
        assert_eq!(
            *rs.holder_index(),
            HolderIndex::rebuild(rs.stores(), rs.distribution())
        );
        // dead-world routing still works (fallback path, old distribution)
        let reqs = vec![LoadRequest {
            pe: 5,
            ranges: RangeSet::new(vec![BlockRange::new(3 * 64, 4 * 64)]),
        }];
        rs.load(&mut cluster, &reqs).unwrap();
    }

    /// A 14-survivor world (r = 4 does not divide 14) — the exact case the
    /// equal-slice layout had to acknowledge — now goes through the full
    /// rebalance_or_acknowledge policy as a REBALANCE.
    #[test]
    fn rebalance_or_acknowledge_rebalances_non_dividing_worlds() {
        let (mut cluster, mut rs, _) = build(16, 64, 4, Some(16), false);
        cluster.kill(&[3, 7]); // p' = 14
        let (_f, map, _) = ulfm::recover(&mut cluster);
        assert!(rs.can_rebalance(&cluster));
        let ran = rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
        let report = ran.expect("p' = 14 must rebalance now");
        assert_eq!(report.new_world, 14);
        assert_eq!(rs.distribution().world(), 14);
        assert!(!rs.distribution().equal_slices()); // 1024 = 14·73 + 2
        assert_eq!(rs.epoch(), cluster.epoch());
    }

    /// When the rebalance discovers an interval with no surviving holder,
    /// the packaged policy degrades to acknowledge instead of failing the
    /// whole handshake: data still held stays loadable in the dead world
    /// and only targeted loads of the lost ranges surface the IDL.
    #[test]
    fn rebalance_or_acknowledge_degrades_to_acknowledge_on_idl() {
        // whole group {1, 5, 9, 13} dies (plus fillers): direct rebalance
        // reports IDL, but the policy must acknowledge and keep routing.
        // Identity layout so the lost slots are exactly 1, 5, 9, 13 and a
        // surviving slot's data is deterministically loadable.
        let (mut cluster, mut rs, _) = build(16, 64, 4, None, false);
        cluster.kill(&[1, 5, 9, 13, 0, 4, 2, 6]);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        assert!(matches!(
            rs.rebalance(&mut cluster, &map),
            Err(Error::IrrecoverableDataLoss { .. })
        ));
        let ran = rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
        assert!(ran.is_none(), "IDL world must degrade to acknowledge");
        assert_eq!(rs.epoch(), cluster.epoch());
        assert_eq!(rs.distribution().world(), 16, "dead-world layout retained");
        // data whose holders survive is still loadable (slot 3: holders
        // {3, 7, 11, 15} all alive)...
        let held = vec![LoadRequest {
            pe: 8,
            ranges: RangeSet::new(vec![BlockRange::new(3 * 64, 4 * 64)]),
        }];
        rs.load(&mut cluster, &held).unwrap();
        // ...and only a targeted load of the LOST slot reports the IDL
        let lost = vec![LoadRequest {
            pe: 8,
            ranges: RangeSet::new(vec![BlockRange::new(64, 2 * 64)]),
        }];
        assert!(matches!(
            rs.load(&mut cluster, &lost),
            Err(Error::IrrecoverableDataLoss { .. })
        ));
    }

    /// The shrink-handshake bugfix: a stale RankMap (a second failure after
    /// the shrink that produced it) must surface Error::StaleRankMap from
    /// rebalance_or_acknowledge BEFORE any policy branch, leaving the store
    /// untouched — not silently acknowledge or rebalance against the wrong
    /// survivor set.
    #[test]
    fn rebalance_or_acknowledge_rejects_stale_rank_map() {
        let (mut cluster, mut rs, _) = build(16, 64, 4, Some(16), false);
        cluster.kill(&HALF_KILLS);
        let (_f, map, _) = ulfm::recover(&mut cluster);
        // another PE dies after the shrink: `map` no longer describes the
        // survivor set
        cluster.kill(&[15]);
        let err = rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap_err();
        assert!(
            matches!(err, Error::StaleRankMap(_)),
            "expected StaleRankMap, got {err:?}"
        );
        // the store is fully untouched: old epoch, old world, stores intact
        assert_eq!(rs.epoch(), 0);
        assert_eq!(rs.distribution().world(), 16);
        assert_eq!(rs.stores()[15].slices().len(), 4);
        // a fresh shrink produces a current map and the policy resumes
        let (map2, _) = ulfm::shrink(&mut cluster);
        rs.rebalance_or_acknowledge(&mut cluster, &map2).unwrap();
        assert_eq!(rs.epoch(), cluster.epoch());
    }

    #[test]
    fn virtual_and_real_rebalance_share_schedule_and_cost() {
        let run = |execution: bool| {
            let (mut cluster, mut rs, _) = build(16, 64, 4, Some(16), execution);
            cluster.kill(&HALF_KILLS);
            let (_f, map, _) = ulfm::recover(&mut cluster);
            let report = rs.rebalance(&mut cluster, &map).unwrap();
            (report, cluster.now())
        };
        let (real, t_real) = run(true);
        let (virt, t_virt) = run(false);
        assert_eq!(real.migrated_bytes, virt.migrated_bytes);
        assert_eq!(real.kept_bytes, virt.kept_bytes);
        assert_eq!(real.transfers, virt.transfers);
        assert_eq!(real.cost, virt.cost);
        assert!((t_real - t_virt).abs() < 1e-12);
    }
}
