//! The simulated world: alive set, message exchange, collectives, clock.
//!
//! `Cluster` plays the role MPI plays for the paper's C++ library. It
//! supports two payload modes:
//!
//! * **Execution mode** ([`Payload::Real`]): every message really carries
//!   its bytes; replica data is physically placed and moved, so tests can
//!   verify bit-exact recovery.
//! * **Cost-model mode** ([`Payload::Virtual`]): messages carry only their
//!   length. The *schedule* (who sends what to whom) is identical — only
//!   the byte buffers are elided, which is what lets the figure benches
//!   scale to the paper's 24 576-PE configurations on one machine.
//!
//! Either way every phase is charged to the simulated clock through the
//! [`network`](crate::simnet::network) model, and failures are injected by
//! [`Cluster::kill`] exactly like the paper's `MPI_Comm_split` methodology
//! (§VI-A).

use crate::config::NetworkConfig;
use crate::error::{Error, Result};
use crate::simnet::network::{allreduce_cost, Accumulator, PhaseCost};
use crate::simnet::topology::Topology;

/// Message payload: real bytes (execution mode) or a byte count only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Payload {
    Real(Vec<u8>),
    Virtual(u64),
}

impl Payload {
    pub fn len(&self) -> u64 {
        match self {
            Payload::Real(v) => v.len() as u64,
            Payload::Virtual(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    /// Real bytes, or an error in cost-model mode.
    pub fn bytes(&self) -> Option<&[u8]> {
        match self {
            Payload::Real(v) => Some(v),
            Payload::Virtual(_) => None,
        }
    }
}

/// One point-to-point message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub src: usize,
    pub dst: usize,
    /// Caller-defined routing tag (ReStore uses the permuted block offset).
    pub tag: u64,
    pub payload: Payload,
}

/// Lifecycle state of one PE slot in the simulated machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeState {
    /// Healthy communicator member.
    Alive,
    /// Healthy but parked in the spare pool — not a communicator member
    /// until `ulfm::substitute`/`ulfm::grow` activates it.
    Spare,
    /// Died while active; reported by [`Cluster::failed`].
    Failed,
    /// Died while parked in the pool. Never a communicator member, so it
    /// does NOT appear in the failed set the survivors agree on — the pool
    /// just got one slot smaller.
    LostSpare,
}

/// The simulated cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    topo: Topology,
    net: NetworkConfig,
    state: Vec<PeState>,
    /// Current communicator: new rank → cluster rank. Starts as the dense
    /// identity over the base ranks (spares excluded); rewritten by the
    /// `ulfm` shrink/substitute/grow primitives.
    comm: Vec<usize>,
    /// Alive communicator members, sorted ascending — maintained
    /// incrementally by [`Cluster::kill`] / spare activation so hot loops
    /// (storm victim picks, weighted corruption sampling) index the alive
    /// set in O(1) instead of filtering the whole `state` vector.
    alive: Vec<u32>,
    n_alive: usize,
    n_spares: usize,
    base_pes: usize,
    clock_s: f64,
    /// Communicator epoch; bumped whenever `ulfm` establishes a new
    /// communicator (shrink, substitute, or grow). `ReStore` records the
    /// epoch its layout was computed at and refuses to route against a
    /// newer one (the handshake: agree → {shrink|substitute|grow} →
    /// reshape → load).
    epoch: u64,
}

impl Cluster {
    /// A cluster with default (OmniPath-class) network parameters.
    pub fn new_execution(pes: usize, pes_per_node: usize) -> Self {
        Self::with_network(pes, pes_per_node, NetworkConfig::default())
    }

    /// A cluster with `spares` extra healthy PEs parked in a spare pool
    /// beyond the `pes` initial communicator members. Spares occupy the
    /// trailing cluster ranks `pes..pes+spares`, count toward
    /// [`Cluster::world`] (the machine size) but not [`Cluster::n_alive`]
    /// (the communicator size), and only join the communicator through
    /// `ulfm::substitute` / `ulfm::grow`.
    pub fn with_spares(pes: usize, pes_per_node: usize, spares: usize) -> Self {
        Self::build(pes, pes_per_node, spares, NetworkConfig::default())
    }

    pub fn with_network(pes: usize, pes_per_node: usize, net: NetworkConfig) -> Self {
        Self::build(pes, pes_per_node, 0, net)
    }

    fn build(pes: usize, pes_per_node: usize, spares: usize, mut net: NetworkConfig) -> Self {
        net.pes_per_node = pes_per_node;
        let total = pes + spares;
        let mut state = vec![PeState::Alive; total];
        state[pes..].fill(PeState::Spare);
        Cluster {
            topo: Topology::new(total, pes_per_node),
            net,
            state,
            comm: (0..pes).collect(),
            alive: (0..pes as u32).collect(),
            n_alive: pes,
            n_spares: spares,
            base_pes: pes,
            clock_s: 0.0,
            epoch: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn network(&self) -> &NetworkConfig {
        &self.net
    }

    /// Machine size: every PE slot, including the spare pool (dead PEs keep
    /// their rank). Rank maps and store arrays are sized by this.
    pub fn world(&self) -> usize {
        self.topo.pes()
    }

    /// Initial communicator size `p` — [`Cluster::world`] minus the spare
    /// pool. This is the world applications are configured against.
    pub fn base_world(&self) -> usize {
        self.base_pes
    }

    pub fn n_alive(&self) -> usize {
        self.n_alive
    }

    /// Healthy PEs still parked in the spare pool.
    pub fn n_spares(&self) -> usize {
        self.n_spares
    }

    pub fn is_alive(&self, rank: usize) -> bool {
        self.state.get(rank) == Some(&PeState::Alive)
    }

    /// Current communicator membership: new rank → cluster rank.
    pub fn comm(&self) -> &[usize] {
        &self.comm
    }

    /// Alive communicator members in increasing cluster-rank order
    /// (allocation-free; parked spares are not members).
    pub fn survivors_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.state
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == PeState::Alive)
            .map(|(r, _)| r)
    }

    /// Communicator members killed so far, in increasing cluster-rank order
    /// (allocation-free; lost spares are not failures the survivors see).
    pub fn failed_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.state
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == PeState::Failed)
            .map(|(r, _)| r)
    }

    /// Healthy pool spares in increasing cluster-rank order.
    pub fn spares_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.state
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == PeState::Spare)
            .map(|(r, _)| r)
    }

    /// Alive ranks in increasing order ([`Cluster::survivors_iter`]
    /// collected — recovery hot loops should use the iterator).
    pub fn survivors(&self) -> Vec<usize> {
        self.survivors_iter().collect()
    }

    /// Alive communicator members as a dense sorted slice — the same
    /// sequence as [`Cluster::survivors_iter`], but indexable in O(1).
    /// Maintained incrementally across kills and spare activations, so
    /// storm victim picks at million-rank worlds cost O(1) instead of an
    /// O(p) scan per event.
    pub fn alive_ranks(&self) -> &[u32] {
        &self.alive
    }

    /// Ranks killed so far ([`Cluster::failed_iter`] collected).
    pub fn failed(&self) -> Vec<usize> {
        self.failed_iter().collect()
    }

    /// Simulated elapsed seconds.
    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Current communicator epoch (0 at construction; +1 per
    /// shrink/substitute/grow).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Install a new communicator (new rank → cluster rank) and advance the
    /// epoch — called by the `ulfm` primitives once the members agree.
    /// Every `ReStore` instance validates its layout epoch against this on
    /// submit/load/repair.
    pub(crate) fn establish_comm(&mut self, comm: Vec<usize>) {
        debug_assert_eq!(comm.len(), self.n_alive, "communicator must cover the alive set");
        debug_assert!(comm.iter().all(|&r| self.is_alive(r)), "dead rank in new communicator");
        self.comm = comm;
        self.epoch += 1;
    }

    /// Promote a pool spare to an active communicator member — called by
    /// `ulfm::substitute`/`ulfm::grow` (which then place it in the new
    /// communicator via [`Cluster::establish_comm`]).
    pub(crate) fn activate_spare(&mut self, rank: usize) {
        debug_assert_eq!(self.state.get(rank), Some(&PeState::Spare), "rank {rank} is not a spare");
        self.state[rank] = PeState::Alive;
        if let Err(at) = self.alive.binary_search(&(rank as u32)) {
            self.alive.insert(at, rank as u32);
        }
        self.n_spares -= 1;
        self.n_alive += 1;
    }

    /// Inject failures (the paper's simulated `MPI_Comm_split` methodology).
    /// Killing an already-dead PE is a no-op; killing a parked spare
    /// silently shrinks the pool (the survivors never observe it).
    pub fn kill(&mut self, ranks: &[usize]) {
        for &r in ranks {
            match self.state.get(r) {
                Some(PeState::Alive) => {
                    self.state[r] = PeState::Failed;
                    if let Ok(at) = self.alive.binary_search(&(r as u32)) {
                        self.alive.remove(at);
                    }
                    self.n_alive -= 1;
                }
                Some(PeState::Spare) => {
                    self.state[r] = PeState::LostSpare;
                    self.n_spares -= 1;
                }
                _ => {}
            }
        }
    }

    /// Advance the simulated clock by an externally computed cost.
    pub fn advance(&mut self, cost: &PhaseCost) {
        self.clock_s += cost.sim_time_s;
    }

    /// Charge local computation time (e.g. a PJRT kernel execution that in
    /// the real cluster runs on every PE in parallel).
    pub fn tick_compute(&mut self, seconds: f64) {
        self.clock_s += seconds;
    }

    /// Sparse all-to-all: deliver `msgs`, charge the phase to the clock.
    ///
    /// All endpoints must be alive — ReStore's schedules are computed
    /// against the survivor set, so a dead endpoint is a routing bug and
    /// surfaces as an error rather than silent loss.
    pub fn exchange(&mut self, msgs: Vec<Msg>) -> Result<(Vec<Msg>, PhaseCost)> {
        let mut acc = Accumulator::new(&self.net, &self.topo);
        for m in &msgs {
            if m.src >= self.world() || m.dst >= self.world() {
                return Err(Error::RankOutOfRange {
                    rank: m.src.max(m.dst),
                    world: self.world(),
                });
            }
            if !self.is_alive(m.src) {
                return Err(Error::DeadPe(m.src));
            }
            if !self.is_alive(m.dst) {
                return Err(Error::DeadPe(m.dst));
            }
            acc.msg(m.src, m.dst, m.payload.len());
        }
        let cost = acc.finish();
        self.clock_s += cost.sim_time_s;
        let mut delivered = msgs;
        // Deterministic delivery order: by (dst, src, tag).
        delivered.sort_by_key(|m| (m.dst, m.src, m.tag));
        Ok((delivered, cost))
    }

    /// Begin an incrementally-built communication phase (for schedules too
    /// large to materialize as a message list — submit at high `p`). All
    /// messages added to the builder belong to ONE concurrent phase.
    pub fn phase(&mut self) -> PhaseBuilder<'_> {
        let acc = Accumulator::new(&self.net, &self.topo);
        PhaseBuilder { cluster: self, acc: PhaseAcc::Owned(acc) }
    }

    /// Like [`Cluster::phase`], but reusing a caller-pooled
    /// [`Accumulator`] (e.g. the one in ReStore's `LoadScratch`): the
    /// accumulator is reset against this cluster's network/topology, so a
    /// `Default` or stale shell is fine, and `commit` leaves it zeroed for
    /// the next phase — no O(p) counter allocation per phase.
    pub fn phase_pooled<'a>(&'a mut self, acc: &'a mut Accumulator) -> PhaseBuilder<'a> {
        acc.reset(&self.net, &self.topo);
        PhaseBuilder { cluster: self, acc: PhaseAcc::Pooled(acc) }
    }

    /// Charge a communication phase given as `(src, dst, bytes)` triples
    /// *without* moving payload bytes — the schedule-driven fast path used
    /// by ReStore's submit/load, whose data movement happens directly
    /// between the in-process stores. Endpoint liveness is validated the
    /// same way as in [`Cluster::exchange`].
    pub fn charge_phase<I>(&mut self, msgs: I) -> Result<PhaseCost>
    where
        I: IntoIterator<Item = (usize, usize, u64)>,
    {
        let mut acc = Accumulator::new(&self.net, &self.topo);
        for (src, dst, bytes) in msgs {
            if src >= self.world() || dst >= self.world() {
                return Err(Error::RankOutOfRange { rank: src.max(dst), world: self.world() });
            }
            if !self.is_alive(src) {
                return Err(Error::DeadPe(src));
            }
            if !self.is_alive(dst) {
                return Err(Error::DeadPe(dst));
            }
            acc.msg(src, dst, bytes);
        }
        let cost = acc.finish();
        self.clock_s += cost.sim_time_s;
        Ok(cost)
    }

    /// Cost-only barrier over the survivors.
    pub fn barrier(&mut self) -> PhaseCost {
        let rounds = (self.n_alive.max(2) as f64).log2().ceil() as u64 * 2;
        let cost = PhaseCost::latency(&self.net, rounds);
        self.clock_s += cost.sim_time_s;
        cost
    }

    /// Allreduce of `elems` f32 values over the survivors: really reduces
    /// the per-PE `contributions` (execution mode) and charges the
    /// binomial-tree cost. `contributions` must hold one slice per survivor.
    pub fn allreduce_f32(&mut self, contributions: &[&[f32]]) -> Result<(Vec<f32>, PhaseCost)> {
        let elems = contributions.first().map(|c| c.len()).unwrap_or(0);
        for c in contributions {
            if c.len() != elems {
                return Err(Error::Config("allreduce: ragged contributions".into()));
            }
        }
        let mut out = vec![0f32; elems];
        for c in contributions {
            for (o, v) in out.iter_mut().zip(c.iter()) {
                *o += *v;
            }
        }
        let cost = allreduce_cost(&self.net, self.n_alive, (elems * 4) as u64);
        self.clock_s += cost.sim_time_s;
        Ok((out, cost))
    }

    /// Cost-only allreduce (for cost-model app runs at large `p`).
    pub fn allreduce_cost_only(&mut self, bytes: u64) -> PhaseCost {
        let cost = allreduce_cost(&self.net, self.n_alive, bytes);
        self.clock_s += cost.sim_time_s;
        cost
    }
}

/// The accumulator behind a [`PhaseBuilder`]: owned per-phase, or a
/// caller-pooled shell (reset on entry, zeroed again on commit).
enum PhaseAcc<'a> {
    Owned(Accumulator),
    Pooled(&'a mut Accumulator),
}

impl PhaseAcc<'_> {
    fn as_mut(&mut self) -> &mut Accumulator {
        match self {
            PhaseAcc::Owned(a) => a,
            PhaseAcc::Pooled(a) => a,
        }
    }
}

/// Incremental builder for one concurrent communication phase.
pub struct PhaseBuilder<'a> {
    cluster: &'a mut Cluster,
    acc: PhaseAcc<'a>,
}

impl<'a> PhaseBuilder<'a> {
    /// Register one message; endpoints must be alive.
    pub fn add(&mut self, src: usize, dst: usize, bytes: u64) -> Result<()> {
        if src >= self.cluster.world() || dst >= self.cluster.world() {
            return Err(Error::RankOutOfRange {
                rank: src.max(dst),
                world: self.cluster.world(),
            });
        }
        if !self.cluster.is_alive(src) {
            return Err(Error::DeadPe(src));
        }
        if !self.cluster.is_alive(dst) {
            return Err(Error::DeadPe(dst));
        }
        self.acc.as_mut().msg(src, dst, bytes);
        Ok(())
    }

    /// Charge `count` fragments handled by `pe` (see `Accumulator::frag`).
    pub fn frag(&mut self, pe: usize, count: u64) {
        self.acc.as_mut().frag(pe, count);
    }

    /// Finish the phase: charge it to the clock and return its cost. A
    /// pooled accumulator is left zeroed for its next phase.
    pub fn commit(mut self) -> PhaseCost {
        let cost = self.acc.as_mut().finish_reset();
        self.cluster.clock_s += cost.sim_time_s;
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(src: usize, dst: usize, bytes: &[u8]) -> Msg {
        Msg { src, dst, tag: 0, payload: Payload::Real(bytes.to_vec()) }
    }

    #[test]
    fn exchange_delivers_real_bytes() {
        let mut c = Cluster::new_execution(4, 2);
        let (got, cost) = c
            .exchange(vec![msg(0, 3, b"hello"), msg(1, 2, b"world")])
            .unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].dst, 2); // sorted by destination
        assert_eq!(got[1].payload.bytes().unwrap(), b"hello");
        assert!(cost.sim_time_s > 0.0);
        assert_eq!(c.now(), cost.sim_time_s);
    }

    #[test]
    fn exchange_rejects_dead_endpoints() {
        let mut c = Cluster::new_execution(4, 2);
        c.kill(&[3]);
        assert!(matches!(
            c.exchange(vec![msg(0, 3, b"x")]),
            Err(Error::DeadPe(3))
        ));
        assert!(matches!(
            c.exchange(vec![msg(3, 0, b"x")]),
            Err(Error::DeadPe(3))
        ));
        assert!(matches!(
            c.exchange(vec![msg(0, 9, b"x")]),
            Err(Error::RankOutOfRange { .. })
        ));
    }

    #[test]
    fn kill_is_idempotent() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[1, 1, 2]);
        assert_eq!(c.n_alive(), 6);
        c.kill(&[1]);
        assert_eq!(c.n_alive(), 6);
        assert_eq!(c.survivors(), vec![0, 3, 4, 5, 6, 7]);
        assert_eq!(c.failed(), vec![1, 2]);
    }

    #[test]
    fn spare_pool_is_parked_outside_the_communicator() {
        let c = Cluster::with_spares(8, 4, 3);
        assert_eq!(c.world(), 11);
        assert_eq!(c.base_world(), 8);
        assert_eq!(c.n_alive(), 8);
        assert_eq!(c.n_spares(), 3);
        assert_eq!(c.comm(), &(0..8).collect::<Vec<_>>()[..]);
        assert_eq!(c.survivors(), (0..8).collect::<Vec<_>>());
        assert_eq!(c.spares_iter().collect::<Vec<_>>(), vec![8, 9, 10]);
        // parked spares are not valid message endpoints
        assert!(!c.is_alive(8));
    }

    #[test]
    fn killing_a_spare_shrinks_the_pool_silently() {
        let mut c = Cluster::with_spares(8, 4, 2);
        c.kill(&[9, 9, 3]);
        assert_eq!(c.n_alive(), 7);
        assert_eq!(c.n_spares(), 1);
        // the survivors only agree on communicator-member deaths
        assert_eq!(c.failed(), vec![3]);
        assert_eq!(c.spares_iter().collect::<Vec<_>>(), vec![8]);
    }

    #[test]
    fn iterators_match_vec_forms() {
        let mut c = Cluster::with_spares(6, 3, 2);
        c.kill(&[1, 4]);
        assert_eq!(c.survivors_iter().collect::<Vec<_>>(), c.survivors());
        assert_eq!(c.failed_iter().collect::<Vec<_>>(), c.failed());
    }

    #[test]
    fn alive_ranks_tracks_survivors_across_kills_and_activations() {
        let mut c = Cluster::with_spares(8, 4, 3);
        let dense = |c: &Cluster| c.alive_ranks().iter().map(|&r| r as usize).collect::<Vec<_>>();
        assert_eq!(dense(&c), c.survivors());

        // kills: communicator members, a spare, a dead repeat, all no-ops on
        // the invariant
        c.kill(&[2, 9, 5, 5]);
        assert_eq!(dense(&c), c.survivors());
        assert_eq!(c.alive_ranks().len(), c.n_alive());

        // spare activation splices the (out-of-order) trailing rank back in
        // sorted position
        c.activate_spare(8);
        assert_eq!(dense(&c), c.survivors());
        assert_eq!(dense(&c), vec![0, 1, 3, 4, 6, 7, 8]);

        // kill everything; both views agree on empty
        c.kill(&(0..c.world()).collect::<Vec<_>>());
        assert_eq!(dense(&c), c.survivors());
        assert!(c.alive_ranks().is_empty());
    }

    #[test]
    fn allreduce_sums_contributions() {
        let mut c = Cluster::new_execution(3, 3);
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let d = [100.0f32, 200.0];
        let (out, cost) = c.allreduce_f32(&[&a, &b, &d]).unwrap();
        assert_eq!(out, vec![111.0, 222.0]);
        assert!(cost.sim_time_s > 0.0);
    }

    #[test]
    fn pooled_phase_matches_owned_phase() {
        let mut c1 = Cluster::new_execution(8, 4);
        let mut c2 = Cluster::new_execution(8, 4);
        let mut acc = Accumulator::default();
        for round in 0..3u64 {
            let mut p1 = c1.phase();
            let mut p2 = c2.phase_pooled(&mut acc);
            for (s, d, b) in [(0usize, 5usize, 4096u64), (1, 6, 64), (2, 2, 128)] {
                p1.add(s, d, b + round).unwrap();
                p2.add(s, d, b + round).unwrap();
                p1.frag(d, 1);
                p2.frag(d, 1);
            }
            assert_eq!(p1.commit(), p2.commit(), "round {round}");
            assert_eq!(c1.now(), c2.now());
        }
    }

    #[test]
    fn pooled_phase_validates_endpoints() {
        let mut c = Cluster::new_execution(4, 2);
        c.kill(&[3]);
        let mut acc = Accumulator::default();
        let mut p = c.phase_pooled(&mut acc);
        assert!(matches!(p.add(0, 3, 8), Err(Error::DeadPe(3))));
        assert!(matches!(p.add(0, 9, 8), Err(Error::RankOutOfRange { .. })));
        p.add(0, 1, 8).unwrap();
        assert!(p.commit().sim_time_s > 0.0);
    }

    #[test]
    fn virtual_payload_costs_like_real() {
        let mut c1 = Cluster::new_execution(4, 2);
        let mut c2 = Cluster::new_execution(4, 2);
        let (_, real) = c1.exchange(vec![msg(0, 3, &[0u8; 4096])]).unwrap();
        let (_, virt) = c2
            .exchange(vec![Msg { src: 0, dst: 3, tag: 0, payload: Payload::Virtual(4096) }])
            .unwrap();
        assert_eq!(real, virt);
    }
}
